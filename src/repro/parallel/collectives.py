"""Collective helpers: coded weighted psum + sharded cross-entropy.

``coded_psum`` is the aggregation primitive of coded gradient aggregation:
inside ``shard_map`` each worker contributes weight * value; the weights (a
tiny replicated input) realize the R-of-(R+K) decode for the current
survivor set without recompilation.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


def coded_psum(tree: PyTree, weight: jnp.ndarray, axis: str) -> PyTree:
    """psum over ``axis`` of weight * leaf (weight is this shard's decode
    coefficient). Call inside shard_map."""
    return jax.tree.map(
        lambda x: jax.lax.psum(x.astype(jnp.float32) * weight, axis), tree
    )


def sharded_cross_entropy(
    logits: jnp.ndarray,   # (..., V_local) — local vocab shard
    labels: jnp.ndarray,   # (...) global vocab ids
    vocab_start: jnp.ndarray,  # () first vocab id of this shard
    axis: str,
) -> jnp.ndarray:
    """Cross-entropy over a vocab-sharded logits tensor without gathering
    the full vocab: max/logsumexp via psum over ``axis`` (shard_map path).

    Used by the explicit-collective training variant; the GSPMD path gets
    the same effect from the partitioner when logits carry a vocab-sharded
    sharding constraint.
    """
    lmax = jax.lax.pmax(logits.max(axis=-1), axis)
    ex = jnp.exp(logits - lmax[..., None])
    denom = jax.lax.psum(ex.sum(axis=-1), axis)
    local = labels - vocab_start
    in_shard = (local >= 0) & (local < logits.shape[-1])
    safe = jnp.clip(local, 0, logits.shape[-1] - 1)
    gold_local = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    gold = jax.lax.psum(jnp.where(in_shard, gold_local - lmax, 0.0), axis)
    return (jnp.log(denom) - gold).mean()
