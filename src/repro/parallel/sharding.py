"""Logical-axis -> mesh-axis sharding rules, per (architecture, shape-kind).

Every param leaf carries a tuple of logical axis names (built at init time
by the same code that builds the values — see models/common.py).  This
module turns those into ``NamedSharding``s for a given mesh:

  * per-arch divisibility drives the rules: heads shard over 'model' when
    n_heads % model_size == 0, else attention falls back to row-parallel
    embed-dim sharding (phi4 24H, whisper 20H, llava 56H, rg 10H, xlstm 4H);
  * MoE expert tensors shard experts over 'model' (EP); very large archs
    (qwen3-235b) additionally FSDP-shard the expert ff dim over 'data';
  * optimizer state gets ZeRO-1 treatment: the largest dim a param leaves
    unsharded is sharded over 'data' when divisible;
  * per-tensor conflicts (two logical axes mapping to the same mesh axis)
    are resolved greedily left-to-right — e.g. (vocab->model, embed->model)
    keeps vocab sharded and replicates embed for that tensor only.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.config import ModelConfig

PyTree = Any

AxisRule = Dict[str, Optional[str]]


def _div(n: int, by: int) -> bool:
    return by > 0 and n % by == 0


def make_rules(cfg: ModelConfig, mesh: Mesh, opts=None) -> AxisRule:
    opts = opts or {}
    model_n = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    rules: AxisRule = {
        "layers": None,
        "head_dim": None,
        "conv": None,
    }
    heads_ok = _div(cfg.n_heads, model_n)
    rules["heads"] = "model" if heads_ok else None
    rules["kv_heads"] = "model" if _div(cfg.n_kv_heads, model_n) else None
    # Fallback when heads don't divide the model axis (DESIGN.md §5): either
    # row-parallel attention via the embed dim (default baseline), or — the
    # §Perf variant — replicate the (small) attention params entirely and
    # keep activations collective-free (opts["attn_replicate"]).
    if not heads_ok and _div(cfg.d_model, model_n) and not opts.get("attn_replicate"):
        rules["embed"] = "model"
    else:
        rules["embed"] = None
    ff = cfg.moe.d_ff_expert if cfg.moe is not None else cfg.d_ff
    ff = ff or int(cfg.d_model * cfg.mlstm_proj_factor)
    rules["ff"] = "model" if _div(ff, model_n) else None
    if cfg.moe is not None and _div(cfg.moe.n_experts, model_n):
        rules["experts"] = "model"
        # FSDP the expert ff dim over 'data' when a model-only shard of the
        # params would blow past ~8 GB/device (qwen3-235b).
        if "data" in mesh.axis_names:
            data_n = dict(zip(mesh.axis_names, mesh.devices.shape))["data"]
            if cfg.n_params() * 2 / max(model_n, 1) > 8e9 and _div(ff, data_n):
                rules["ff"] = "data"
    else:
        rules["experts"] = None
    rules["vocab"] = "model" if _div(cfg.vocab, model_n) else None
    w = cfg.lru_width or cfg.d_model
    rules["state"] = "model" if _div(w, model_n) else None
    return rules


def spec_for_axes(axes: Tuple[Optional[str], ...], rules: AxisRule) -> P:
    """Resolve one tensor's logical axes to a PartitionSpec, dropping
    per-tensor duplicate mesh-axis assignments (greedy, left-to-right)."""
    used = set()
    out = []
    for ax in axes:
        m = rules.get(ax) if ax is not None else None
        if m is None or m in used:
            out.append(None)
        else:
            out.append(m)
            used.add(m)
    return P(*out)


def param_shardings(mesh: Mesh, axes_tree: PyTree, rules: AxisRule) -> PyTree:
    is_axes = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x
    )
    return jax.tree.map(
        lambda a: NamedSharding(mesh, spec_for_axes(a, rules)),
        axes_tree,
        is_leaf=is_axes,
    )


def opt_state_shardings(mesh: Mesh, axes_tree: PyTree, rules: AxisRule,
                        shapes_tree: PyTree) -> PyTree:
    """ZeRO-1: like the param sharding, plus shard the largest remaining
    unsharded dim over 'data' when divisible."""
    names = dict(zip(mesh.axis_names, mesh.devices.shape))
    data_n = names.get("data", 1)
    is_axes = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x
    )

    def one(axes, shape):
        spec = list(spec_for_axes(axes, rules))
        if "data" not in spec and data_n > 1:
            # largest unsharded, data-divisible dim
            cands = [
                (shape[i], i) for i in range(len(shape))
                if spec[i] is None and _div(shape[i], data_n)
            ]
            if cands:
                _, i = max(cands)
                spec[i] = "data"
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, axes_tree, shapes_tree, is_leaf=is_axes)


def data_mesh(devices=None) -> Mesh:
    """1-D 'data' mesh over the given devices (default: all local devices).

    Used by the Monte-Carlo engine's device-sharded batch runner
    (``core.engine.Engine(shard=True)``) and available to any other
    embarrassingly-parallel batch fan-out."""
    devs = list(devices) if devices is not None else jax.local_devices()
    return Mesh(np.asarray(devs), ("data",))


def batch_spec(mesh: Mesh, batch: int, extra_dims: int = 1) -> P:
    """Shard the leading batch dim over ('pod','data') as divisibility
    allows; remaining dims replicated."""
    names = dict(zip(mesh.axis_names, mesh.devices.shape))
    axes = []
    if "pod" in names and "data" in names:
        if _div(batch, names["pod"] * names["data"]):
            axes = ["pod", "data"]
        elif _div(batch, names["data"]):
            axes = ["data"]
    elif "data" in names and _div(batch, names["data"]):
        axes = ["data"]
    # Normalize: a single mesh axis is a bare name, multiple axes a tuple —
    # consumers index bspec[0] and expect the bare-name form for one axis.
    first = None if not axes else axes[0] if len(axes) == 1 else tuple(axes)
    return P(first, *([None] * extra_dims))


def cache_shardings(mesh: Mesh, cfg: ModelConfig, cache_tree: PyTree,
                    batch: int, rules: AxisRule) -> PyTree:
    """Decode caches: batch over ('pod','data'); KV heads over 'model' when
    divisible, else head_dim over 'model' (qwen3 kv=4, granite kv=1).

    Cache layouts: attn {k,v}: (groups, B, Hkv, T, hd); recurrent states
    carry (groups, B, ...) — batch-shard dim 1, and shard the widest state
    dim over 'model' when the rules allow."""
    names = dict(zip(mesh.axis_names, mesh.devices.shape))
    model_n = names.get("model", 1)
    bspec = batch_spec(mesh, batch, extra_dims=0)
    b_axis = bspec[0]
    kv_ok = _div(cfg.n_kv_heads, model_n)
    hd_ok = _div(cfg.head_dim_, model_n)

    def one(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        nd = leaf.ndim
        if name == "enc_out" and nd == 3:  # whisper encoder output (B, T, D)
            return NamedSharding(mesh, P(b_axis, None, None))
        if name in ("k", "v") and nd == 5:
            if kv_ok:
                return NamedSharding(mesh, P(None, b_axis, "model", None, None))
            if hd_ok:
                return NamedSharding(mesh, P(None, b_axis, None, None, "model"))
            return NamedSharding(mesh, P(None, b_axis, None, None, None))
        if name == "C" and nd == 5:  # mLSTM matrix state (g,B,H,dh,dh)
            heads_ok = _div(cfg.n_heads, model_n)
            return NamedSharding(
                mesh, P(None, b_axis, "model" if heads_ok else None, None, None)
            )
        if nd >= 2:
            spec = [None, b_axis] + [None] * (nd - 2)
            # shard a trailing state dim over model if divisible (rg-lru h)
            if name in ("h", "conv") and rules.get("state") == "model" and \
                    _div(leaf.shape[-1], model_n):
                spec[-1] = "model"
            return NamedSharding(mesh, P(*spec))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map_with_path(one, cache_tree)


def estimate_bytes(tree: PyTree) -> int:
    return sum(
        int(np.prod(x.shape)) * x.dtype.itemsize for x in jax.tree.leaves(tree)
    )
