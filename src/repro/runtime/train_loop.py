"""Training step factories: microbatched/remat GSPMD step + coded-DP step.

``make_train_step`` builds the production step: gradient accumulation over a
``lax.scan`` of microbatches (fp32 accumulator), remat per layer group,
AdamW update — this is what the multi-pod dry-run lowers.

``make_coded_train_step`` is the paper's contribution wired into DP: an
explicit ``shard_map`` over the 'data' axis where every worker computes its
own microbatch gradient plus (round-robin) one parity gradient — the
gradient of a sparse sum of neighbour microbatches — and aggregation is a
*weighted* psum whose weights (a tiny input) realize the R-of-(R+K) decode
for the current survivor set.  Straggler/failure tolerance without
recompilation; the no-straggler weight pattern makes the parity term a
no-op add.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from ..core import gradient_coding
from ..models.model import Model
from ..optim import adamw

PyTree = Any


def _reshape_micro(batch: Dict[str, jnp.ndarray], n_micro: int):
    def r(x):
        b = x.shape[0]
        assert b % n_micro == 0, f"batch {b} % n_micro {n_micro}"
        return x.reshape(n_micro, b // n_micro, *x.shape[1:])

    return {k: r(v) for k, v in batch.items() if v is not None}


def make_train_step(
    model: Model,
    opt_cfg: adamw.AdamWConfig,
    n_microbatches: int = 1,
    pre_shaped: bool = False,
    unroll: bool = False,
) -> Callable:
    """(params, opt_state, batch) -> (params', opt_state', metrics).

    ``pre_shaped``: batch arrays already carry the leading (n_micro, mb, ...)
    layout (the data pipeline / dry-run produce this so no cross-shard
    reshape of the batch dim is compiled in).
    ``unroll``: unroll the microbatch scan (dry-run cost-analysis fidelity).
    """

    def train_step(params, opt_state, batch):
        mb = batch if pre_shaped else _reshape_micro(batch, n_microbatches)

        def micro(carry, b):
            gsum, lsum = carry
            loss, grads = jax.value_and_grad(model.loss_fn)(params, b)
            gsum = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32), gsum, grads
            )
            return (gsum, lsum + loss), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (gsum, lsum), _ = jax.lax.scan(
            micro, (zeros, jnp.zeros(())), mb, unroll=unroll
        )
        grads = jax.tree.map(lambda g: g / n_microbatches, gsum)
        params, opt_state, metrics = adamw.apply(opt_cfg, params, grads, opt_state)
        metrics["loss"] = lsum / n_microbatches
        return params, opt_state, metrics

    return train_step


def make_eval_step(model: Model) -> Callable:
    def eval_step(params, batch):
        return model.loss_fn(params, batch)

    return eval_step


# ---------------------------------------------------------------------------
# Coded data parallelism (the paper's technique in the training loop)
# ---------------------------------------------------------------------------

def make_coded_train_step(
    model: Model,
    opt_cfg: adamw.AdamWConfig,
    mesh: Mesh,
    n_parity: Optional[int] = None,
    axis: str = "data",
    seed: int = 0,
):
    """Coded-DP training step over ``axis`` (R workers = axis size).

    Returns (train_step, code, weight_table) where
      train_step(params, opt_state, batch, weights) and
      batch["tokens"]: (R * mb, T) sharded over ``axis``,
      weights: (R+K',) decode weights — K' = parities *padded to R* so every
      worker runs exactly one parity pass (zero-degree pads contribute
      nothing; uniform compute keeps the step shape static).
    """
    R = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]
    K = n_parity if n_parity is not None else max(1, R // 4)
    code = gradient_coding.make_gradient_code(R, K, seed=seed)
    assigns = gradient_coding.parity_assignments(code)
    # worker w's parity: the k with k % R == w (or empty)
    per_worker = [[] for _ in range(R)]
    for k, nbrs in enumerate(assigns):
        per_worker[k % R].append((k, nbrs))
    d_max = max((len(n) for _, ns in enumerate(assigns) for n in [ns]), default=1)
    # parity neighbour table per worker: (R, d_max) source ids + coefficients
    nbr = np.zeros((R, d_max), np.int32)
    nmask = np.zeros((R, d_max), np.float32)
    pid = np.full((R,), -1, np.int32)  # which coded row this worker's parity is
    for w in range(R):
        if per_worker[w]:
            k, nbrs = per_worker[w][0]  # one parity per worker max (K <= R)
            row = code.R + k
            pid[w] = row
            nbr[w, : len(nbrs)] = nbrs
            # coefficient of each neighbour in this parity row
            cmap = {int(s): float(c) for s, c in
                    zip(code.idx[row][code.mask[row]],
                        code.coef[row][code.mask[row]])}
            nmask[w, : len(nbrs)] = [cmap[int(s)] for s in nbrs]
    nbr_j = jnp.asarray(nbr)
    nmask_j = jnp.asarray(nmask)
    pid_j = jnp.asarray(pid)

    def local_grads(params, batch_all, weights):
        """Runs per-device under shard_map: batch_all (R, mb, T) replicated
        (each worker reads its own + neighbour microbatches)."""
        w_idx = jax.lax.axis_index(axis)
        own = jax.tree.map(lambda x: x[w_idx], batch_all)
        _, g_own = jax.value_and_grad(model.loss_fn)(params, own)

        def parity_loss(p):
            mbs = jax.tree.map(lambda x: x[nbr_j[w_idx]], batch_all)  # (d_max, mb, T)
            losses = jax.vmap(lambda b: model.loss_fn(p, b))(
                jax.tree.map(lambda x: x, mbs)
            )
            return (losses * nmask_j[w_idx]).sum()

        g_par = jax.grad(parity_loss)(params)
        w_own = weights[w_idx]
        w_par = jnp.where(pid_j[w_idx] >= 0,
                          weights[jnp.maximum(pid_j[w_idx], 0)], 0.0)
        combined = jax.tree.map(
            lambda a, b: (w_own * a.astype(jnp.float32)
                          + w_par * b.astype(jnp.float32)),
            g_own, g_par,
        )
        summed = jax.tree.map(
            lambda g: jax.lax.psum(g, axis), combined
        )
        loss = jax.lax.psum(model.loss_fn(params, own) * w_own, axis)
        return summed, loss

    from jax.experimental.shard_map import shard_map

    sharded = shard_map(
        local_grads,
        mesh=mesh,
        in_specs=(P(), P(), P()),
        out_specs=(P(), P()),
        check_rep=False,
    )

    def train_step(params, opt_state, batch_all, weights):
        grads, loss = sharded(params, batch_all, weights)
        grads = jax.tree.map(lambda g: g / R, grads)
        params, opt_state, metrics = adamw.apply(opt_cfg, params, grads, opt_state)
        metrics["loss"] = loss / R
        return params, opt_state, metrics

    pats, ws = gradient_coding.weight_table(code, max_stragglers=max(1, K // 2), seed=seed)
    return train_step, code, (pats, ws)
