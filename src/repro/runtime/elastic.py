"""Elastic runtime: failure detection -> mesh shrink -> reshard-restore.

The CCP timeout ladder (Alg. 1 l.13-14) feeds this layer: a worker whose
backoff crosses the drop threshold is declared dead, the runtime rebuilds a
mesh over the surviving devices, restores the latest checkpoint with the
*new* shardings (checkpoint.restore reshards transparently), and training
resumes; re-admission grows the mesh back the same way.

In-step tolerance (no restart) is the coded gradient aggregation in
runtime/train_loop.py; this module handles the slower path when capacity
actually changes.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh

from .. import checkpoint as ckpt_mod
from ..core.scheduler import CCPScheduler


def submesh(devices: Sequence, data: int, model: int) -> Mesh:
    """Build a (data, model) mesh over an explicit device subset."""
    devs = np.asarray(devices[: data * model]).reshape(data, model)
    return Mesh(devs, ("data", "model"))


@dataclasses.dataclass
class ElasticConfig:
    ckpt_dir: str
    ckpt_every: int = 20
    min_data: int = 1


class ElasticTrainer:
    """Drives train/fail/shrink/restore cycles.

    ``build`` is a factory: (mesh) -> (state, step_fn, shardings) where
    state = (params, opt_state); it is re-invoked after every topology
    change so shardings/compilation always match the current mesh.
    """

    def __init__(self, cfg: ElasticConfig, build: Callable, all_devices=None):
        self.cfg = cfg
        self.build = build
        self.devices = list(all_devices if all_devices is not None else jax.devices())
        self.failed: set[int] = set()
        self.ckpt = ckpt_mod.AsyncCheckpointer(cfg.ckpt_dir)
        self.step = 0
        self.mesh: Optional[Mesh] = None
        self.state = None
        self.step_fn = None
        self.shardings = None
        self.scheduler: Optional[CCPScheduler] = None

    # -- topology ----------------------------------------------------------

    def alive(self):
        return [d for i, d in enumerate(self.devices) if i not in self.failed]

    def _shape_for(self, n: int, model: int):
        data = max(n // model, self.cfg.min_data)
        return data, model

    def rebuild(self, model_axis: int):
        alive = self.alive()
        data, model = self._shape_for(len(alive), model_axis)
        self.mesh = submesh(alive, data, model)
        self.state, self.step_fn, self.shardings = self.build(self.mesh)
        self.scheduler = CCPScheduler(n_workers=data)
        if ckpt_mod.latest_step(self.cfg.ckpt_dir) is not None:
            target = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), self.state
            )
            self.state, meta = ckpt_mod.restore(
                self.cfg.ckpt_dir, None, target, self.shardings
            )
            self.step = int(meta.get("step", self.step))

    # -- events ------------------------------------------------------------

    def fail_device(self, idx: int, model_axis: int):
        """Simulated hard failure: checkpoint state is the recovery point."""
        self.ckpt.wait()
        self.failed.add(idx)
        self.rebuild(model_axis)

    def recover_device(self, idx: int, model_axis: int):
        self.failed.discard(idx)
        self.rebuild(model_axis)

    # -- training ----------------------------------------------------------

    def run(self, n_steps: int, batch_fn: Callable[[int, Mesh], dict]):
        losses = []
        for _ in range(n_steps):
            batch = batch_fn(self.step, self.mesh)
            self.state, metrics = self.step_fn(self.state, batch)
            losses.append(float(metrics["loss"]))
            self.step += 1
            if self.step % self.cfg.ckpt_every == 0:
                self.ckpt.save_async(self.step, self.state,
                                     metadata={"step": self.step})
        self.ckpt.wait()
        return losses
