"""Serving runtime: batched prefill/decode with CCP request dispatch.

One ``ServeEngine`` wraps a model + params and exposes generate() over
batched requests.  ``CCPDispatcher`` spreads request batches over multiple
(possibly heterogeneous) engine replicas using the paper's estimator: each
replica is a "helper", a batch is a "packet", and dispatch rates follow
E[beta] estimates with timeout backoff — the serving-side realization of
Algorithm 1.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.scheduler import CCPScheduler
from ..models.model import Model


@dataclasses.dataclass
class ServeEngine:
    model: Model
    params: object
    max_len: int = 512
    sample: str = "greedy"

    def __post_init__(self):
        self._prefill = jax.jit(self.model.prefill)
        self._decode = jax.jit(self.model.decode_step, donate_argnums=(2,))

    def generate(
        self,
        tokens: np.ndarray,           # (B, T) prompts (right-aligned, padded)
        n_new: int,
        embeds: Optional[np.ndarray] = None,
    ) -> np.ndarray:
        B, T = tokens.shape
        cache = self.model.init_cache(B, self.max_len)
        toks = jnp.asarray(tokens)
        if embeds is not None:
            logits, cache = self._prefill(self.params, toks[:, :-1], cache,
                                          jnp.asarray(embeds))
        else:
            logits, cache = self._prefill(self.params, toks[:, :-1], cache)
        out = []
        cur = toks[:, -1:]
        for _ in range(n_new):
            logits, cache = self._decode(self.params, cur, cache)
            cur = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
            out.append(np.asarray(cur))
        return np.concatenate(out, axis=1)


class CCPDispatcher:
    """Dispatch request batches over replicas with eq. (23) allocation."""

    def __init__(self, replicas: Sequence[Callable[[np.ndarray], np.ndarray]]):
        self.replicas = list(replicas)
        self.sched = CCPScheduler(n_workers=len(self.replicas))

    def run(self, batches: List[np.ndarray], rounds: Optional[int] = None):
        """Process batches round-by-round; per round, allocation follows the
        current E[beta] estimates. Returns (results, per_round_alloc)."""
        results = [None] * len(batches)
        allocs = []
        i = 0
        while i < len(batches):
            n_left = len(batches) - i
            alloc = self.sched.allocation(min(n_left, len(self.replicas) * 4))
            allocs.append(alloc.copy())
            durations = np.zeros(len(self.replicas))
            for w, n_w in enumerate(alloc):
                t0 = time.perf_counter()
                for _ in range(int(n_w)):
                    if i >= len(batches):
                        break
                    results[i] = self.replicas[w](batches[i])
                    i += 1
                durations[w] = time.perf_counter() - t0
            per_unit = np.where(alloc > 0, durations, np.nan)
            # feed only workers that actually ran something this round
            obs = np.where(alloc > 0, durations / np.maximum(alloc, 1), np.nan)
            obs = np.where(np.isnan(obs), np.nanmean(obs), obs)
            self.sched._work = np.maximum(alloc, 1)
            self.sched.observe_step(obs * np.maximum(alloc, 1))
        return results, allocs
