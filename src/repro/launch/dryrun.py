import os
os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"
)
"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes and extract memory/cost/collective analyses.

The two lines above MUST run before any jax import (jax locks the device
count at first init).  512 host devices cover both the 16x16 single-pod and
the 2x16x16 multi-pod mesh.

Methodology.  XLA cost analysis counts a rolled while-body ONCE, and fully
unrolling a 94-layer x 8-microbatch step does not compile in reasonable
time on this container's single CPU core.  Each cell therefore gets:

  1. the PRODUCTION lowering — rolled scans, full microbatch count, the
     real shardings: the compile proof for the mesh, the memory_analysis
     source, and the once-per-step (ENTRY-computation) collective wire;
  2. two GROUP-DIFFERENCING cost probes — the same step lowered for
     1-group and 2-group variants of the arch with layer/kv-chunk scans
     unrolled (tiny HLO, seconds to compile).  One group's exact
     fwd(+bwd+opt+grad-AR) cost is C2 - C1; totals assemble as

       train: T = M*(L*G_micro + E_micro) + L*G_optAR + E_optAR
       serve: T = C1 + (L-1)*(C2 - C1)

     with the per-group optimizer/grad-all-reduce split computed
     analytically from sharded param element counts (~15 flop / ~26 B per
     element; ring AR wire = 2*S*(P-1)/P over the DP axes).

Per-time-step scans (xlstm cells) stay rolled inside the probes — flagged
``time_scan_undercount`` and corrected analytically in EXPERIMENTS.md.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                  # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-27b \
      --shape train_4k --mesh single                            # one cell
Results are cached as JSON under experiments/dryrun/.
"""

import argparse
import dataclasses
import json
import pathlib
import time
import traceback

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES, applicable, get_config
from repro.kernels.flash_attention.ops import set_chunk_opts
from repro.launch.mesh import make_production_mesh
from repro.launch import specs as specs_mod
from repro.models.model import build_model
from repro.optim import adamw
from repro.parallel import sharding as shd
from repro.runtime.train_loop import make_train_step
from repro.utils import hlo as hlo_mod
from repro.utils import roofline as rf

OUT_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

N_MICRO = {"default": 8}
OPT_FLOPS_PER_ELEM = 15.0
OPT_BYTES_PER_ELEM = 26.0


def _mem_summary(compiled):
    try:
        m = compiled.memory_analysis()
    except Exception:
        return {}
    if m is None:
        return {}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        v = getattr(m, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def _spec_div(sh, mesh) -> int:
    names = dict(zip(mesh.axis_names, mesh.devices.shape))
    div = 1
    for ax in sh.spec:
        if ax is None:
            continue
        for a in (ax if isinstance(ax, tuple) else (ax,)):
            div *= names.get(a, 1)
    return div


def _sharded_elems(struct_tree, shard_tree, mesh) -> float:
    total = 0.0
    structs = jax.tree.leaves(struct_tree)
    shards = jax.tree.leaves(shard_tree,
                             is_leaf=lambda x: isinstance(x, NamedSharding))
    for st, sh in zip(structs, shards):
        n = float(np.prod(st.shape)) if st.shape else 1.0
        total += n / _spec_div(sh, mesh)
    return total


def _sharded_bytes(struct_tree, shard_tree, mesh) -> float:
    total = 0.0
    structs = jax.tree.leaves(struct_tree)
    shards = jax.tree.leaves(shard_tree,
                             is_leaf=lambda x: isinstance(x, NamedSharding))
    for st, sh in zip(structs, shards):
        n = float(np.prod(st.shape)) if st.shape else 1.0
        total += n * st.dtype.itemsize / _spec_div(sh, mesh)
    return total


def _wire(hlo_text, entry_only=False):
    ops = hlo_mod.parse_collectives(hlo_text)
    if entry_only:
        ops = [o for o in ops if o.in_entry]
    return rf.wire_bytes(ops)


def _dp_size(mesh) -> int:
    names = dict(zip(mesh.axis_names, mesh.devices.shape))
    return names.get("data", 1) * names.get("pod", 1)


def _shrink(cfg, n_groups: int):
    """Variant config with n_groups repeats of the block pattern (and, for
    enc-dec, a matching encoder depth)."""
    kw = dict(n_layers=n_groups * cfg.pattern_period)
    if cfg.enc_dec:
        kw["n_enc_layers"] = n_groups
    return dataclasses.replace(cfg, **kw)


def _probe_once(cfg_v, shape, mesh, rules, kind, micro_gb, opts=None):
    """Lower one (small) variant and return its total flops/bytes/wire and
    per-device param element count."""
    opts = opts or {}
    set_chunk_opts(chunk=4096, unroll=True)
    model = build_model(cfg_v, use_pallas=False, remat=True, unroll_scans=True,
                        remat_policy=opts.get("remat_policy", "full"),
                        ring_local=bool(opts.get("ring_local")))
    params_struct, axes = specs_mod.params_and_axes_struct(model)
    p_shard = shd.param_shardings(mesh, axes, rules)
    elems = _sharded_elems(params_struct, p_shard, mesh)
    if kind == "train":
        o_struct = specs_mod.opt_struct(params_struct)
        o_shard = adamw.AdamWState(
            step=NamedSharding(mesh, P()),
            m=shd.opt_state_shardings(mesh, axes, rules,
                                      specs_mod.shapes_of(params_struct)),
            v=shd.opt_state_shardings(mesh, axes, rules,
                                      specs_mod.shapes_of(params_struct)),
        )
        micro_shape = dataclasses.replace(shape, global_batch=micro_gb)
        b_struct = specs_mod.batch_struct(cfg_v, micro_shape, 1)
        b_shard = specs_mod.batch_shardings(mesh, b_struct)
        step = make_train_step(model, adamw.AdamWConfig(), 1, pre_shaped=True,
                               unroll=True)
        fn = jax.jit(step, in_shardings=(p_shard, o_shard, b_shard),
                     out_shardings=(p_shard, o_shard, None))
        with mesh:
            compiled = fn.lower(params_struct, o_struct, b_struct).compile()
    else:
        tok_struct, cache_struct, emb_struct = specs_mod.serve_structs(
            model, cfg_v, shape)
        tok_sh, cache_sh, emb_sh = specs_mod.serve_shardings(
            mesh, cfg_v, shape, cache_struct, rules)
        if kind == "prefill" and emb_struct is not None:
            f = lambda p, t, c, e: model.prefill(p, t, c, embeds=e)
            in_sh = (p_shard, tok_sh, cache_sh, emb_sh)
            args = (params_struct, tok_struct, cache_struct, emb_struct)
        elif kind == "prefill":
            f = lambda p, t, c: model.prefill(p, t, c)
            in_sh = (p_shard, tok_sh, cache_sh)
            args = (params_struct, tok_struct, cache_struct)
        else:
            f = lambda p, t, c: model.decode_step(p, t, c)
            in_sh = (p_shard, tok_sh, cache_sh)
            args = (params_struct, tok_struct, cache_struct)
        fn = jax.jit(f, in_shardings=in_sh, out_shardings=(None, cache_sh))
        with mesh:
            compiled = fn.lower(*args).compile()
    cost = dict(compiled.cost_analysis() or {})
    hlo_text = compiled.as_text()
    return dict(
        flops=float(cost.get("flops", 0.0)),
        bytes=float(cost.get("bytes accessed", 0.0)),
        wire=_wire(hlo_text),
        elems=elems,
    )


def _assemble(cfg, shape, mesh, c1, c2, n_micro):
    """Group-differencing assembly (see module docstring)."""
    L = cfg.n_groups
    dp = _dp_size(mesh)
    d_elems = max(c2["elems"] - c1["elems"], 0.0)   # one group, per device
    e_elems = max(c1["elems"] - d_elems, 0.0)       # embed/head/norms
    out = {}
    if shape.kind == "train":
        ar = lambda elems: 2.0 * elems * 4.0 * (dp - 1) / dp if dp > 1 else 0.0
        g_opt = {
            "flops": OPT_FLOPS_PER_ELEM * d_elems,
            "bytes": OPT_BYTES_PER_ELEM * d_elems,
            "wire": ar(d_elems),
        }
        e_opt = {
            "flops": OPT_FLOPS_PER_ELEM * e_elems,
            "bytes": OPT_BYTES_PER_ELEM * e_elems,
            "wire": ar(e_elems),
        }
        for k in ("flops", "bytes", "wire"):
            g = max(c2[k] - c1[k], 0.0)
            g_micro = max(g - g_opt[k], 0.0)
            e_all = max(c1[k] - g, 0.0)
            e_micro = max(e_all - e_opt[k], 0.0)
            out[k] = (n_micro * (L * g_micro + e_micro)
                      + L * g_opt[k] + e_opt[k])
    else:
        for k in ("flops", "bytes", "wire"):
            g = max(c2[k] - c1[k], 0.0)
            out[k] = c1[k] + (L - 1) * g
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool, verbose: bool = True,
             opts=None):
    """opts: perf-iteration knob overrides, e.g. {"remat_policy": "dots",
    "n_micro": 4} — used by the §Perf hillclimb (benchmarks/perf_iter.py)."""
    opts = opts or {}
    shape = SHAPES[shape_name]
    cfg = get_config(arch, param_dtype="bfloat16", compute_dtype="bfloat16")
    skip = applicable(cfg, shape)
    mesh_name = "multi" if multi_pod else "single"
    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "status": "skip", "skip_reason": skip,
    }
    if skip is not None:
        return result

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    rules = shd.make_rules(cfg, mesh, opts)
    mflops = rf.model_flops(cfg, shape, n_dev)

    from repro.models.attention import set_attn_opts
    from repro.models.moe import set_moe_opts

    set_moe_opts(constrain=bool(opts.get("moe_constrain")),
                 a2a_mesh=mesh if opts.get("moe_a2a") else None)
    if opts.get("kv_gather"):
        # batch stays data-sharded when divisible; k/v replicate over model
        bspec = shd.batch_spec(mesh, shape.global_batch, extra_dims=0)[0]
        set_attn_opts(kv_gather=bspec if bspec else ())
    else:
        set_attn_opts(kv_gather=None)

    # ---- production lowering: compile proof + memory + entry collectives --
    set_chunk_opts(chunk=1024, unroll=False)
    model_prod = build_model(cfg, use_pallas=False, remat=True,
                             unroll_scans=False,
                             remat_policy=opts.get("remat_policy", "full"),
                             ring_local=bool(opts.get("ring_local")))
    params_struct, axes = specs_mod.params_and_axes_struct(model_prod)
    p_shard = shd.param_shardings(mesh, axes, rules)

    n_micro = 1
    if shape.kind == "train":
        n_micro = opts.get("n_micro") or N_MICRO.get(arch, N_MICRO["default"])
        dp = _dp_size(mesh)
        while (shape.global_batch // n_micro) % dp and n_micro > 1:
            n_micro //= 2
        o_struct = specs_mod.opt_struct(params_struct)
        o_shard = adamw.AdamWState(
            step=NamedSharding(mesh, P()),
            m=shd.opt_state_shardings(mesh, axes, rules,
                                      specs_mod.shapes_of(params_struct)),
            v=shd.opt_state_shardings(mesh, axes, rules,
                                      specs_mod.shapes_of(params_struct)),
        )
        b_struct = specs_mod.batch_struct(cfg, shape, n_micro)
        b_shard = specs_mod.batch_shardings(mesh, b_struct)
        step = make_train_step(model_prod, adamw.AdamWConfig(), n_micro,
                               pre_shaped=True)
        fn = jax.jit(step, in_shardings=(p_shard, o_shard, b_shard),
                     out_shardings=(p_shard, o_shard, None),
                     donate_argnums=(0, 1))
        with mesh:
            compiled_prod = fn.lower(params_struct, o_struct, b_struct).compile()
        cache_sh = cache_struct = None
    else:
        tok_struct, cache_struct, emb_struct = specs_mod.serve_structs(
            model_prod, cfg, shape)
        tok_sh, cache_sh, emb_sh = specs_mod.serve_shardings(
            mesh, cfg, shape, cache_struct, rules)
        if shape.kind == "prefill" and emb_struct is not None:
            f = lambda p, t, c, e: model_prod.prefill(p, t, c, embeds=e)
            in_sh = (p_shard, tok_sh, cache_sh, emb_sh)
            args = (params_struct, tok_struct, cache_struct, emb_struct)
        elif shape.kind == "prefill":
            f = lambda p, t, c: model_prod.prefill(p, t, c)
            in_sh = (p_shard, tok_sh, cache_sh)
            args = (params_struct, tok_struct, cache_struct)
        else:
            f = lambda p, t, c: model_prod.decode_step(p, t, c)
            in_sh = (p_shard, tok_sh, cache_sh)
            args = (params_struct, tok_struct, cache_struct)
        fn = jax.jit(f, in_shardings=in_sh, out_shardings=(None, cache_sh),
                     donate_argnums=(len(args) - 1,) if shape.kind == "decode" else ())
        with mesh:
            compiled_prod = fn.lower(*args).compile()
    mem = _mem_summary(compiled_prod) or {}
    prod_hlo = compiled_prod.as_text()
    once_wire = _wire(prod_hlo, entry_only=True)
    colls = hlo_mod.collective_summary(prod_hlo)
    t_prod = round(time.time() - t0, 1)

    # ---- cost probes: 1-group and 2-group variants -------------------------
    micro_gb = shape.global_batch // n_micro
    c1 = _probe_once(_shrink(cfg, 1), shape, mesh, rules, shape.kind,
                     micro_gb, opts)
    c2 = _probe_once(_shrink(cfg, 2), shape, mesh, rules, shape.kind,
                     micro_gb, opts)
    tot = _assemble(cfg, shape, mesh, c1, c2, n_micro)

    roof = rf.Roofline(
        compute_s=tot["flops"] / rf.PEAK_FLOPS,
        memory_s=tot["bytes"] / rf.HBM_BW,
        collective_s=tot["wire"] / rf.ICI_BW,
        hlo_flops=tot["flops"], hbm_bytes=tot["bytes"], wire_bytes=tot["wire"],
        model_flops=mflops,
    )

    mem["param_bytes_per_device_est"] = _sharded_bytes(params_struct, p_shard, mesh)
    if cache_struct is not None:
        mem["cache_bytes_per_device_est"] = _sharded_bytes(
            cache_struct, cache_sh, mesh)

    has_time_scan = any(b in ("mlstm", "slstm") for b in cfg.block_pattern)
    result.update(
        status="ok",
        compile_s=round(time.time() - t0, 1),
        compile_s_production=t_prod,
        n_devices=n_dev,
        n_micro=n_micro if shape.kind == "train" else None,
        time_scan_undercount=bool(has_time_scan),
        memory=mem,
        collectives=colls,
        once_wire=once_wire,
        probe={"c1": c1, "c2": c2},
        roofline=roof.to_dict(),
        rules={k: v for k, v in rules.items()},
    )
    if verbose:
        r = roof
        print(
            f"  ok in {result['compile_s']}s | flops/dev={r.hlo_flops:.3e} "
            f"| hbm={r.hbm_bytes:.3e} | wire={r.wire_bytes:.3e} "
            f"| dominant={r.dominant} | roofline_frac="
            f"{None if r.roofline_fraction is None else round(r.roofline_fraction, 4)}",
            flush=True,
        )
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch id (default: all)")
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default=None, choices=["single", "multi"])
    ap.add_argument("--force", action="store_true", help="recompute cached cells")
    args = ap.parse_args()

    OUT_DIR.mkdir(parents=True, exist_ok=True)
    arches = [args.arch] if args.arch else ARCH_IDS
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [args.mesh] if args.mesh else ["single", "multi"]

    n_ok = n_skip = n_fail = 0
    for arch in arches:
        for shape in shapes:
            for mesh_name in meshes:
                tag = f"{arch}__{shape}__{mesh_name}"
                out = OUT_DIR / f"{tag}.json"
                if out.exists() and not args.force:
                    prev = json.loads(out.read_text())
                    print(f"[cached] {tag}: {prev['status']}")
                    n_ok += prev["status"] == "ok"
                    n_skip += prev["status"] == "skip"
                    n_fail += prev["status"] == "fail"
                    continue
                print(f"[run] {tag}", flush=True)
                try:
                    res = run_cell(arch, shape, mesh_name == "multi")
                except Exception as e:
                    traceback.print_exc()
                    res = {
                        "arch": arch, "shape": shape, "mesh": mesh_name,
                        "status": "fail", "error": f"{type(e).__name__}: {e}",
                    }
                out.write_text(json.dumps(res, indent=1, default=str))
                n_ok += res["status"] == "ok"
                n_skip += res["status"] == "skip"
                n_fail += res["status"] == "fail"
                if res["status"] == "skip":
                    print(f"  skip: {res['skip_reason']}")
                elif res["status"] == "fail":
                    print(f"  FAIL: {res['error']}")
    print(f"\ndry-run complete: {n_ok} ok / {n_skip} skip / {n_fail} fail")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
