"""Serving launcher: batched generate with optional CCP dispatch replicas.

  PYTHONPATH=src python -m repro.launch.serve --arch phi4-mini-3.8b --smoke \
      --requests 16 --batch 4 --prompt-len 16 --new-tokens 8 --replicas 2
"""

import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--replicas", type=int, default=1)
    ap.add_argument("--slow-factor", type=float, default=0.0,
                    help="artificial delay (s) on odd replicas — demo of CCP "
                         "dispatch over heterogeneous replicas")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import time

    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.models import build_model
    from repro.runtime.serve_loop import CCPDispatcher, ServeEngine

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(args.seed))
    engine = ServeEngine(model, params, max_len=args.max_len)

    rng = np.random.default_rng(args.seed)
    batches = [
        rng.integers(0, cfg.vocab, size=(args.batch, args.prompt_len)).astype(np.int32)
        for _ in range(args.requests)
    ]

    def make_replica(i):
        def run(b):
            if args.slow_factor and i % 2 == 1:
                time.sleep(args.slow_factor)
            return engine.generate(b, n_new=args.new_tokens)
        return run

    t0 = time.time()
    if args.replicas > 1:
        disp = CCPDispatcher([make_replica(i) for i in range(args.replicas)])
        results, allocs = disp.run(batches)
        print(f"dispatch allocations: first={allocs[0].tolist()} "
              f"last={allocs[-1].tolist()}")
    else:
        results = [make_replica(0)(b) for b in batches]
    dt = time.time() - t0
    toks = sum(r.shape[0] * r.shape[1] for r in results)
    print(f"served {len(results)} request batches / {toks} tokens "
          f"in {dt:.2f}s ({toks/dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
