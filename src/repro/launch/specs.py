"""ShapeDtypeStruct input builders + sharding trees for every (arch, shape).

``input_specs`` returns stand-ins only — weak-type-correct, shardable, no
device allocation — which is what ``jit(...).lower()`` consumes in the
dry-run.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.shapes import Shape
from ..models.config import ModelConfig
from ..models.model import Model
from ..optim import adamw
from ..parallel import sharding as shd

Struct = jax.ShapeDtypeStruct


def text_len(cfg: ModelConfig, seq_len: int) -> int:
    """Text-token length for a total sequence budget (vlm prefix eats into
    the assigned seq_len)."""
    if cfg.frontend == "vision_stub":
        return max(seq_len - cfg.n_patches, 1)
    return seq_len


def batch_struct(cfg: ModelConfig, shape: Shape, n_micro: int) -> Dict[str, Struct]:
    gb = shape.global_batch
    t = text_len(cfg, shape.seq_len)
    mb = gb // n_micro
    cdt = jnp.dtype(cfg.compute_dtype)
    out = {
        "tokens": Struct((n_micro, mb, t), jnp.int32),
        "labels": Struct((n_micro, mb, t), jnp.int32),
    }
    if cfg.frontend == "audio_stub":
        out["embeds"] = Struct((n_micro, mb, cfg.enc_frames, cfg.d_model), cdt)
    elif cfg.frontend == "vision_stub":
        out["embeds"] = Struct((n_micro, mb, cfg.n_patches, cfg.d_model), cdt)
    return out


def batch_shardings(mesh: Mesh, batch: Dict[str, Struct]) -> Dict[str, Any]:
    out = {}
    for k, v in batch.items():
        gb = v.shape[1]
        spec = shd.batch_spec(mesh, gb, extra_dims=v.ndim - 2)
        out[k] = NamedSharding(mesh, P(None, *spec))
    return out


def params_and_axes_struct(model: Model, seed: int = 0):
    """Shape-only params via eval_shape; the (static) axes tree is captured
    as a tracing side effect — no allocation happens for full-size configs."""
    captured = {}

    def init_vals(k):
        vals, axes = model.init(k)
        captured["axes"] = axes
        return vals

    struct = jax.eval_shape(init_vals, jax.random.PRNGKey(seed))
    return struct, captured["axes"]


def opt_struct(params_struct):
    return jax.eval_shape(adamw.init, params_struct)


def shapes_of(tree):
    return jax.tree.map(lambda x: x.shape, tree)


def serve_structs(model: Model, cfg: ModelConfig, shape: Shape):
    """(tokens, cache) structs for prefill/decode lowering."""
    b = shape.global_batch
    cdt = jnp.dtype(cfg.compute_dtype)
    max_len = shape.seq_len
    cache = jax.eval_shape(lambda: model.init_cache(b, max_len, cdt))
    if shape.kind == "prefill":
        tokens = Struct((b, text_len(cfg, shape.seq_len)), jnp.int32)
    else:
        tokens = Struct((b, 1), jnp.int32)
    embeds = None
    if cfg.frontend == "audio_stub":
        embeds = Struct((b, cfg.enc_frames, cfg.d_model), cdt)
    elif cfg.frontend == "vision_stub" and shape.kind == "prefill":
        embeds = Struct((b, cfg.n_patches, cfg.d_model), cdt)
    return tokens, cache, embeds


def serve_shardings(mesh: Mesh, cfg: ModelConfig, shape: Shape, cache_struct,
                    rules) -> Tuple[Any, Any, Any]:
    b = shape.global_batch
    tok = NamedSharding(mesh, shd.batch_spec(mesh, b, extra_dims=1))
    cache = shd.cache_shardings(mesh, cfg, cache_struct, b, rules)
    emb = NamedSharding(mesh, shd.batch_spec(mesh, b, extra_dims=2))
    return tok, cache, emb
