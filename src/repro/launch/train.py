"""Training launcher.

Real (executing) runs on whatever devices exist; the production-mesh path
is exercised by dryrun.py.  Supports the full framework: sharded params,
microbatched/remat step, CCP scheduler telemetry, coded-DP (optional),
async checkpointing, deterministic data.

  PYTHONPATH=src python -m repro.launch.train --arch phi4-mini-3.8b --smoke \
      --steps 50 --batch 8 --seq 64 --devices 8 --mesh 8,1 --ckpt /tmp/ck
"""

import argparse
import os


def _parse_args():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--mesh", default="1,1", help="data,model axis sizes")
    ap.add_argument("--devices", type=int, default=0,
                    help="force N host devices (must be set before jax init)")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--coded-dp", action="store_true",
                    help="use the coded-DP (R-of-R+K) training step")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    return ap.parse_args()


def main():
    args = _parse_args()
    if args.devices:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.devices}"
        )
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro import checkpoint as ck
    from repro.configs import get_config
    from repro.core.scheduler import CCPScheduler
    from repro.data import SyntheticLM
    from repro.launch.mesh import make_host_mesh
    from repro.models import build_model
    from repro.optim import adamw
    from repro.parallel import sharding as shd
    from repro.runtime.train_loop import make_coded_train_step, make_train_step

    overrides = {}
    for kv in filter(None, os.environ.get("REPRO_TRAIN_OVERRIDES", "").split(",")):
        k, v = kv.split("=")
        overrides[k] = int(v)
    cfg = get_config(args.arch, smoke=args.smoke, **overrides)
    model = build_model(cfg, remat=True)
    data_n, model_n = (int(x) for x in args.mesh.split(","))
    mesh = make_host_mesh(data=data_n, model=model_n)
    rules = shd.make_rules(cfg, mesh)

    params, axes = model.init(jax.random.PRNGKey(args.seed))
    p_sh = shd.param_shardings(mesh, axes, rules)
    params = jax.device_put(params, p_sh)
    opt_cfg = adamw.AdamWConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5),
                                total_steps=args.steps)
    opt_state = adamw.init(params)

    data = SyntheticLM(cfg.vocab, args.seq, args.batch, n_micro=args.n_micro,
                       seed=args.seed)
    start = 0
    ckpt = None
    if args.ckpt:
        ckpt = ck.AsyncCheckpointer(args.ckpt)
        if args.resume and ck.latest_step(args.ckpt) is not None:
            tgt = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                {"params": params, "opt": opt_state},
            )
            state, meta = ck.restore(args.ckpt, None, tgt,
                                     {"params": p_sh, "opt": None})
            params, opt_state = state["params"], state["opt"]
            start = int(meta.get("step", 0))
            print(f"resumed from step {start}")

    sched = CCPScheduler(n_workers=data_n)
    if args.coded_dp:
        step_fn, code, (pats, ws) = make_coded_train_step(
            model, opt_cfg, mesh, seed=args.seed)
        w0 = jnp.asarray(ws[0])

        def run_step(params, opt_state, batch):
            # batch (n_micro, mb, T) -> coded step wants (R, mb', T)
            tok = batch["tokens"].reshape(data_n, -1, batch["tokens"].shape[-1])
            lab = batch["labels"].reshape(data_n, -1, batch["labels"].shape[-1])
            return step_fn(params, opt_state, {"tokens": tok, "labels": lab}, w0)
    else:
        raw = make_train_step(model, opt_cfg, args.n_micro, pre_shaped=True)
        jit_step = jax.jit(raw, donate_argnums=(0, 1))

        def run_step(params, opt_state, batch):
            return jit_step(params, opt_state, batch)

    t_start = time.time()
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch(step).items()}
        t0 = time.time()
        with mesh:
            params, opt_state, metrics = run_step(params, opt_state, batch)
        loss = float(metrics["loss"])
        dt = time.time() - t0
        sched.observe_step(np.full(data_n, dt))
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e} {dt*1e3:.0f}ms")
        if ckpt and (step + 1) % args.ckpt_every == 0:
            ckpt.save_async(step + 1, {"params": params, "opt": opt_state},
                            metadata={"step": step + 1})
    if ckpt:
        ckpt.wait()
    print(f"done: {args.steps - start} steps in {time.time()-t_start:.1f}s, "
          f"final loss {loss:.4f}")
    return loss


if __name__ == "__main__":
    main()
