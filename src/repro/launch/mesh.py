"""Production meshes (assignment spec).

Defined as FUNCTIONS so importing this module never touches jax device
state; the dry-run sets XLA_FLAGS before any jax import.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod:  (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices exist — examples/tests."""
    return jax.make_mesh((data, model), ("data", "model"))
