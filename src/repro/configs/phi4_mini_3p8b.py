"""phi4-mini-3.8b [dense] — RoPE + SwiGLU + GQA.

32L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=200064. [arXiv:2412.08905]
Note: 24 heads do not divide the 16-way model axis; the sharding rules fall
back to embed-dim (row-parallel) sharding for attention (DESIGN.md §5).
"""

from ..models.config import ModelConfig

ID = "phi4-mini-3.8b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ID,
        n_layers=32,
        d_model=3072,
        n_heads=24,
        n_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab=200064,
        block_pattern=("attn",),
        mlp="swiglu",
        tie_embeddings=True,
        family="dense",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ID + "-smoke",
        n_layers=2,
        d_model=48,
        n_heads=6,
        n_kv_heads=2,
        head_dim=8,
        d_ff=128,
        vocab=512,
        block_pattern=("attn",),
        mlp="swiglu",
        tie_embeddings=True,
        family="dense",
    )
