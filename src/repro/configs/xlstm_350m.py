"""xlstm-350m [ssm] — sLSTM + mLSTM blocks (attention-free).

24L d_model=1024 4H d_ff=0 vocab=50304, alternating mLSTM/sLSTM blocks.
Recurrent state is O(1) in sequence length -> runs the long_500k shape.
[arXiv:2405.04517]
"""

from ..models.config import ModelConfig

ID = "xlstm-350m"


def config() -> ModelConfig:
    return ModelConfig(
        name=ID,
        n_layers=24,
        d_model=1024,
        n_heads=4,
        n_kv_heads=4,
        head_dim=256,
        d_ff=0,
        vocab=50304,
        block_pattern=("mlstm", "slstm"),
        mlstm_proj_factor=2.0,
        tie_embeddings=False,
        family="ssm",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ID + "-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=0,
        vocab=512,
        block_pattern=("mlstm", "slstm"),
        mlstm_proj_factor=2.0,
        tie_embeddings=False,
        family="ssm",
    )
