"""recurrentgemma-2b [hybrid] — RG-LRU + local attention, ~1:2 ratio.

26L d_model=2560 10H (MQA kv=1) d_ff=7680 vocab=256000, lru_width=2560,
window=2048 local attention, GeGLU MLP, gemma norm conventions.  The
repeating unit is a 13-block pattern (x2 groups = 26 layers) placing
attention every third block, 8 attention layers total — matching the
published 1:2 placement. [arXiv:2402.19427]

Bounded window + O(1) recurrent state -> runs the long_500k shape.
"""

from ..models.config import ModelConfig

ID = "recurrentgemma-2b"

_PATTERN = (
    "rglru", "rglru", "attn_local",
    "rglru", "rglru", "attn_local",
    "rglru", "rglru", "attn_local",
    "rglru", "rglru", "attn_local",
    "rglru",
)


def config() -> ModelConfig:
    return ModelConfig(
        name=ID,
        n_layers=26,
        d_model=2560,
        n_heads=10,
        n_kv_heads=1,
        head_dim=256,
        d_ff=7680,
        vocab=256000,
        block_pattern=_PATTERN,
        window=2048,
        lru_width=2560,
        conv_width=4,
        mlp="geglu",
        rms_scale_plus_one=True,
        embed_scale=True,
        tie_embeddings=True,
        family="hybrid",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ID + "-smoke",
        n_layers=6,
        d_model=64,
        n_heads=2,
        n_kv_heads=1,
        head_dim=32,
        d_ff=128,
        vocab=512,
        block_pattern=("rglru", "rglru", "attn_local"),
        window=8,
        lru_width=64,
        conv_width=4,
        mlp="geglu",
        rms_scale_plus_one=True,
        embed_scale=True,
        tie_embeddings=True,
        family="hybrid",
    )
