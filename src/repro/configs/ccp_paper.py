"""The paper's own experiment configurations (§6 simulation setups)."""

from ..core.simulator import ScenarioConfig

ID = "ccp-paper"

# Fig. 3: a_n = 0.5, mu in {1,2,4}, 10-20 Mbps links, N=100.
FIG3 = {
    1: ScenarioConfig(N=100, scenario=1, mu_choices=(1.0, 2.0, 4.0),
                      a_mode="const", a_const=0.5),
    2: ScenarioConfig(N=100, scenario=2, mu_choices=(1.0, 2.0, 4.0),
                      a_mode="const", a_const=0.5),
}

# Fig. 4: a_n = 1/mu_n, mu in {1,3,9}.
FIG4 = {
    1: ScenarioConfig(N=100, scenario=1, mu_choices=(1.0, 3.0, 9.0),
                      a_mode="inv_mu"),
    2: ScenarioConfig(N=100, scenario=2, mu_choices=(1.0, 3.0, 9.0),
                      a_mode="inv_mu"),
}

# Fig. 5: N=10 helpers, slow links (0.1-0.2 Mbps), Scenario-2 runtimes.
FIG5 = ScenarioConfig(N=10, scenario=2, mu_choices=(1.0, 2.0, 4.0),
                      a_mode="const", a_const=0.5,
                      rate_lo=0.1e6, rate_hi=0.2e6)

# Efficiency table: R = 8000, Fig-4 helper distribution.
EFFICIENCY = FIG4[1]

R_SWEEP = (500, 1000, 2000, 4000, 6000, 8000, 10000)
REPS = 200
