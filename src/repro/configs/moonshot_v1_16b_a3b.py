"""moonshot-v1-16b-a3b [moe] — Kimi/Moonlight-16B-A3B.

48L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=163840, MoE 64 experts
top-6 (+2 shared, Moonlight-style). [hf:moonshotai/Moonlight-16B-A3B]
"""

from ..models.config import ModelConfig, MoEConfig

ID = "moonshot-v1-16b-a3b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ID,
        n_layers=48,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        head_dim=128,
        d_ff=1408,
        vocab=163840,
        moe=MoEConfig(n_experts=64, top_k=6, d_ff_expert=1408, n_shared=2),
        block_pattern=("attn",),
        mlp="swiglu",
        rope_theta=50000.0,
        tie_embeddings=False,
        family="moe",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ID + "-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=48,
        vocab=512,
        # capacity_factor 8: no token dropping at smoke-test batch sizes, so
        # prefill+decode exactly matches the full forward pass.
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=48, n_shared=1,
                      capacity_factor=8.0),
        block_pattern=("attn",),
        mlp="swiglu",
        tie_embeddings=False,
        family="moe",
    )
