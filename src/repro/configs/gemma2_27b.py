"""gemma2-27b [dense] — local+global alternating attention, logit softcaps.

46L d_model=4608 32H (GQA kv=16) d_ff=36864 vocab=256000, head_dim=128,
window=4096 on local layers, attn softcap 50, final softcap 30, post-block
RMSNorms, (1+w) RMSNorm scales, sqrt(d) embedding scale. [arXiv:2408.00118]
"""

from ..models.config import ModelConfig

ID = "gemma2-27b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ID,
        n_layers=46,
        d_model=4608,
        n_heads=32,
        n_kv_heads=16,
        head_dim=128,
        d_ff=36864,
        vocab=256000,
        block_pattern=("attn_local", "attn_global"),
        window=4096,
        attn_softcap=50.0,
        final_softcap=30.0,
        mlp="geglu",
        post_block_norm=True,
        rms_scale_plus_one=True,
        embed_scale=True,
        tie_embeddings=True,
        family="dense",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ID + "-smoke",
        n_layers=4,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab=512,
        block_pattern=("attn_local", "attn_global"),
        window=8,
        attn_softcap=50.0,
        final_softcap=30.0,
        mlp="geglu",
        post_block_norm=True,
        rms_scale_plus_one=True,
        embed_scale=True,
        tie_embeddings=True,
        family="dense",
    )
