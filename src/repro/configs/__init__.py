"""Architecture registry: ``--arch <id>`` resolves here."""

from __future__ import annotations

from typing import Dict

from ..models.config import ModelConfig
from . import (
    gemma2_27b,
    granite_20b,
    llava_next_34b,
    mistral_nemo_12b,
    moonshot_v1_16b_a3b,
    phi4_mini_3p8b,
    qwen3_moe_235b_a22b,
    recurrentgemma_2b,
    whisper_large_v3,
    xlstm_350m,
)
from .shapes import SHAPES, Shape, applicable  # noqa: F401

_MODULES = [
    moonshot_v1_16b_a3b,
    qwen3_moe_235b_a22b,
    gemma2_27b,
    granite_20b,
    mistral_nemo_12b,
    phi4_mini_3p8b,
    whisper_large_v3,
    xlstm_350m,
    recurrentgemma_2b,
    llava_next_34b,
]

REGISTRY: Dict[str, object] = {m.ID: m for m in _MODULES}
ARCH_IDS = list(REGISTRY.keys())


def get_config(arch: str, smoke: bool = False, **overrides) -> ModelConfig:
    if arch not in REGISTRY:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    cfg = REGISTRY[arch].smoke_config() if smoke else REGISTRY[arch].config()
    if overrides:
        import dataclasses

        cfg = dataclasses.replace(cfg, **overrides)
    return cfg
