"""llava-next-34b [vlm] — Yi-34B-class decoder backbone, vision STUB.

60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.  The anyres vision
tower + projector are stubbed: input_specs() provides precomputed
(B, n_patches=2880, 7168) patch embeddings prepended to the token stream.
[hf:llava-hf/llava-v1.6-*; backbone per Yi-34B]
Note: 56 heads do not divide the 16-way model axis; sharding falls back to
embed-dim (row-parallel) for attention (DESIGN.md §5).
"""

from ..models.config import ModelConfig

ID = "llava-next-34b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ID,
        n_layers=60,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        head_dim=128,
        d_ff=20480,
        vocab=64000,
        block_pattern=("attn",),
        mlp="swiglu",
        rope_theta=5000000.0,
        frontend="vision_stub",
        n_patches=2880,
        tie_embeddings=False,
        family="vlm",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ID + "-smoke",
        n_layers=2,
        d_model=64,
        n_heads=8,
        n_kv_heads=2,
        head_dim=8,
        d_ff=128,
        vocab=512,
        block_pattern=("attn",),
        mlp="swiglu",
        frontend="vision_stub",
        n_patches=8,
        tie_embeddings=False,
        family="vlm",
    )
