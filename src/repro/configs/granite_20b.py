"""granite-20b [dense] — IBM Granite 20B code model, MQA.

52L d_model=6144 48H (GQA kv=1 = MQA) d_ff=24576 (4d, GELU) vocab=49152.
[arXiv:2405.04324]  Spec says llama-arch; we keep RoPE + the published
4d GELU MLP (documented in DESIGN.md).
"""

from ..models.config import ModelConfig

ID = "granite-20b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ID,
        n_layers=52,
        d_model=6144,
        n_heads=48,
        n_kv_heads=1,
        head_dim=128,
        d_ff=24576,
        vocab=49152,
        block_pattern=("attn",),
        mlp="gelu",
        tie_embeddings=False,
        family="dense",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ID + "-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=1,
        head_dim=16,
        d_ff=256,
        vocab=512,
        block_pattern=("attn",),
        mlp="gelu",
        tie_embeddings=False,
        family="dense",
    )
