"""Assigned input shapes (same 4 for every LM arch) and applicability rules."""

from __future__ import annotations

import dataclasses
from typing import Optional

from ..models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": Shape("train_4k", "train", 4096, 256),
    "prefill_32k": Shape("prefill_32k", "prefill", 32768, 32),
    "decode_32k": Shape("decode_32k", "decode", 32768, 128),
    "long_500k": Shape("long_500k", "decode", 524288, 1),
}


def applicable(cfg: ModelConfig, shape: Shape) -> Optional[str]:
    """None if the (arch, shape) cell runs; else a skip reason (recorded in
    the dry-run table per the assignment's shape rules)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return "full-attention arch: long_500k needs sub-quadratic attention"
    return None
