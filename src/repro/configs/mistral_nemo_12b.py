"""mistral-nemo-12b [dense] — 128k-context dense GQA model.

40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072, head_dim=128,
rope theta 1e6 for 128k ctx. [hf:mistralai/Mistral-Nemo-Base-2407]
"""

from ..models.config import ModelConfig

ID = "mistral-nemo-12b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ID,
        n_layers=40,
        d_model=5120,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab=131072,
        block_pattern=("attn",),
        mlp="swiglu",
        rope_theta=1000000.0,
        tie_embeddings=False,
        family="dense",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ID + "-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab=512,
        block_pattern=("attn",),
        mlp="swiglu",
        tie_embeddings=False,
        family="dense",
    )
