"""whisper-large-v3 [audio] — encoder-decoder backbone, conv frontend STUB.

32L decoder + 32L encoder, d_model=1280 20H (kv=20) d_ff=5120 vocab=51866,
LayerNorm + GELU + biases, sinusoidal positions (no RoPE).  The mel/conv
frontend is a stub: input_specs() provides precomputed (B, 1500, 1280)
frame embeddings per the assignment. [arXiv:2212.04356]
"""

from ..models.config import ModelConfig

ID = "whisper-large-v3"


def config() -> ModelConfig:
    return ModelConfig(
        name=ID,
        n_layers=32,
        d_model=1280,
        n_heads=20,
        n_kv_heads=20,
        head_dim=64,
        d_ff=5120,
        vocab=51866,
        block_pattern=("attn",),
        mlp="gelu",
        norm="layernorm",
        attn_bias=True,
        enc_dec=True,
        n_enc_layers=32,
        enc_frames=1500,
        frontend="audio_stub",
        tie_embeddings=True,
        family="audio",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ID + "-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab=512,
        block_pattern=("attn",),
        mlp="gelu",
        norm="layernorm",
        attn_bias=True,
        enc_dec=True,
        n_enc_layers=2,
        enc_frames=16,
        frontend="audio_stub",
        tie_embeddings=True,
        family="audio",
    )
