"""qwen3-moe-235b-a22b [moe] — Qwen3-235B-A22B family.

94L d_model=4096 64H (GQA kv=4) d_ff_expert=1536 vocab=151936, MoE 128
experts top-8, head_dim=128 (per HF config). [hf:Qwen/Qwen3-30B-A3B]
Simplification noted in DESIGN.md: Qwen3's qk-norm is omitted.
"""

from ..models.config import ModelConfig, MoEConfig

ID = "qwen3-moe-235b-a22b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ID,
        n_layers=94,
        d_model=4096,
        n_heads=64,
        n_kv_heads=4,
        head_dim=128,
        d_ff=1536,
        vocab=151936,
        moe=MoEConfig(n_experts=128, top_k=8, d_ff_expert=1536),
        block_pattern=("attn",),
        mlp="swiglu",
        rope_theta=1000000.0,
        tie_embeddings=False,
        family="moe",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ID + "-smoke",
        n_layers=2,
        d_model=64,
        n_heads=8,
        n_kv_heads=2,
        head_dim=8,
        d_ff=32,
        vocab=512,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32,
                      capacity_factor=8.0),
        block_pattern=("attn",),
        mlp="swiglu",
        tie_embeddings=False,
        family="moe",
    )
