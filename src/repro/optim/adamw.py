"""AdamW + schedules + global-norm clipping, built from scratch (no optax).

State is a pytree mirroring params: {m, v} in fp32 plus scalar step.  The
distribution layer gives m/v ZeRO-1 shardings (see parallel/sharding.py).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


class AdamWState(NamedTuple):
    step: jnp.ndarray   # () int32
    m: PyTree           # fp32, like params
    v: PyTree           # fp32, like params


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"  # cosine | linear | constant
    min_lr_frac: float = 0.1


def schedule_lr(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    s = step.astype(jnp.float32)
    warm = jnp.minimum(s / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip(
        (s - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    if cfg.schedule == "cosine":
        decay = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    elif cfg.schedule == "linear":
        decay = 1.0 - (1.0 - cfg.min_lr_frac) * frac
    else:
        decay = jnp.asarray(1.0)
    return cfg.lr * warm * decay


def init(params: PyTree) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros))


def global_norm(tree: PyTree) -> jnp.ndarray:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)
    ))


def apply(
    cfg: AdamWConfig, params: PyTree, grads: PyTree, state: AdamWState,
) -> Tuple[PyTree, AdamWState, dict]:
    """One optimizer step. Returns (params', state', metrics)."""
    gnorm = global_norm(grads)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
    step = state.step + 1
    lr = schedule_lr(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m_new = cfg.b1 * m + (1 - cfg.b1) * g32
        v_new = cfg.b2 * v + (1 - cfg.b2) * g32 * g32
        mh = m_new / b1c
        vh = v_new / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return p_new.astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(step, new_m, new_v), metrics
