from .adamw import AdamWConfig, AdamWState, apply, init, schedule_lr  # noqa: F401
