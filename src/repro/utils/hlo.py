"""Parse compiled (post-SPMD) HLO text for collective operations.

``compiled.cost_analysis()`` has FLOPs and HBM bytes but no collective
traffic; we recover it by summing result-shape bytes of every all-reduce /
all-gather / reduce-scatter / all-to-all / collective-permute in the
optimized HLO, with replica-group sizes for the per-op ring cost model in
utils/roofline.py.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLL = r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
# result types between '=' and the op name; ops may be fused/async (-start)
_LINE = re.compile(
    r"=\s*(?P<types>[^=]*?)\s*(?P<op>" + _COLL + r")(?P<suffix>-start)?\("
)
_SHAPE = re.compile(r"(?P<dt>[a-z]+\d*)\[(?P<dims>[0-9,]*)\]")
_GROUPS = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    bytes: int          # result bytes (per device)
    group_size: int
    in_entry: bool = True  # ENTRY computation (once per step) vs. loop body


def _shape_bytes(types: str) -> int:
    total = 0
    for m in _SHAPE.finditer(types):
        dt = m.group("dt")
        if dt not in _DTYPE_BYTES:
            continue
        dims = m.group("dims")
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA.search(line)
    if m:
        return int(m.group(2))  # [n_groups, group_size]
    return 1


def parse_collectives(hlo_text: str) -> List[CollectiveOp]:
    ops: List[CollectiveOp] = []
    in_entry = False
    for line in hlo_text.splitlines():
        # computation headers sit at column 0: "ENTRY %main ... {" / "%body ... {"
        if line and not line[0].isspace() and "{" in line:
            in_entry = line.lstrip().startswith("ENTRY")
            continue
        if "-done(" in line:
            continue  # async completion re-lists the type; start was counted
        m = _LINE.search(line)
        if not m:
            continue
        kind = m.group("op")
        b = _shape_bytes(m.group("types"))
        if b == 0:
            continue
        ops.append(CollectiveOp(kind=kind, bytes=b,
                                group_size=_group_size(line), in_entry=in_entry))
    return ops


def collective_summary(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """{kind: {count, bytes}} over the whole module."""
    out: Dict[str, Dict[str, float]] = defaultdict(lambda: {"count": 0, "bytes": 0})
    for op in parse_collectives(hlo_text):
        out[op.kind]["count"] += 1
        out[op.kind]["bytes"] += op.bytes
    return dict(out)
