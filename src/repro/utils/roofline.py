"""Roofline-term derivation from a compiled dry-run artifact.

Hardware constants (assignment spec; TPU v5e-class):
  197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link ICI.

Per-op collective wire-cost model (ring algorithms, per device):
  all-reduce         2 * S * (P-1)/P      (S = result bytes = shard bytes)
  all-gather         S * (P-1)/P          (S = result bytes = full bytes)
  reduce-scatter     S * (P-1)            (S = result bytes = full/P)
  all-to-all         S * (P-1)/P
  collective-permute S
Multi-link torus parallelism is not credited — terms are conservative.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from .hlo import CollectiveOp, parse_collectives

PEAK_FLOPS = 197e12     # bf16 FLOP/s per chip
HBM_BW = 819e9          # B/s per chip
ICI_BW = 50e9           # B/s per link


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    hlo_flops: float          # per device
    hbm_bytes: float          # per device
    wire_bytes: float         # per device
    model_flops: Optional[float] = None  # 6ND-style useful flops (per device)

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        """Lower-bound step time: overlapped terms -> max."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> Optional[float]:
        if self.model_flops is None or self.hlo_flops == 0:
            return None
        return self.model_flops / self.hlo_flops

    @property
    def roofline_fraction(self) -> Optional[float]:
        """MODEL_FLOPS / (bound_s * PEAK): how close the step is to the
        compute roofline, counting only useful flops."""
        if self.model_flops is None or self.bound_s == 0:
            return None
        return self.model_flops / (self.bound_s * PEAK_FLOPS)

    def to_dict(self) -> Dict:
        return {
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "hlo_flops": self.hlo_flops,
            "hbm_bytes": self.hbm_bytes,
            "wire_bytes": self.wire_bytes,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def wire_bytes(ops: List[CollectiveOp]) -> float:
    total = 0.0
    for op in ops:
        p = max(op.group_size, 1)
        if op.kind == "all-reduce":
            total += 2.0 * op.bytes * (p - 1) / p
        elif op.kind == "all-gather":
            total += op.bytes * (p - 1) / p
        elif op.kind == "reduce-scatter":
            total += op.bytes * (p - 1)
        elif op.kind == "all-to-all":
            total += op.bytes * (p - 1) / p
        elif op.kind == "collective-permute":
            total += op.bytes
    return total


def derive(
    cost_analysis: Dict[str, float],
    hlo_text: str,
    model_flops_per_device: Optional[float] = None,
) -> Roofline:
    flops = float(cost_analysis.get("flops", 0.0))
    bytes_accessed = float(cost_analysis.get("bytes accessed", 0.0))
    ops = parse_collectives(hlo_text)
    wb = wire_bytes(ops)
    return Roofline(
        compute_s=flops / PEAK_FLOPS,
        memory_s=bytes_accessed / HBM_BW,
        collective_s=wb / ICI_BW,
        hlo_flops=flops,
        hbm_bytes=bytes_accessed,
        wire_bytes=wb,
        model_flops=model_flops_per_device,
    )


def model_flops(cfg, shape, n_devices: int) -> float:
    """Useful (6ND-style) FLOPs per device for one step of the given shape.

    train: 6 * N_active * tokens  (fwd 2ND + bwd 4ND)
    prefill: 2 * N_active * tokens
    decode: 2 * N_active * batch  (one token per sequence)
    Attention flops beyond the 6ND convention are excluded (convention).
    """
    n = cfg.n_active_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        total = 6.0 * n * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        total = 2.0 * n * tokens
    else:  # decode
        total = 2.0 * n * shape.global_batch
    return total / n_devices
