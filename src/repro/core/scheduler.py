"""CCP-driven runtime scheduler: the paper's estimator over device telemetry.

On a real cluster the "helpers" are hosts/pods and the radio ACKs become
step-completion timestamps; the estimator arithmetic (eqs. 3-8) is shared
with the simulator via repro.core.ccp.  The scheduler:

  * keeps per-worker E[beta] (time per unit work) estimates via eq. (5),
  * reallocates microbatches between steps with the optimal allocation of
    eq. (23) (integerized by largest remainder),
  * applies timeout backoff (Alg. 1 l.13) and flags workers for the elastic
    layer once the backoff crosses ``drop_after`` doublings — the paper's
    "offload less and less to an unresponsive helper" taken to its limit.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from . import ccp as ccp_mod
from . import theory

__all__ = ["CCPScheduler"]


@dataclasses.dataclass
class CCPScheduler:
    n_workers: int
    alpha: float = 0.25
    timeout_factor: float = 2.0
    drop_after: int = 3          # backoff doublings before declaring dead
    cfg: ccp_mod.CCPConfig = None
    state: ccp_mod.CCPState = None
    _clock: Optional[np.ndarray] = None  # per-worker busy-time virtual clock
    _work: Optional[np.ndarray] = None   # last allocation (units per worker)

    def __post_init__(self):
        # Bx/Br/Back are vestigial here (telemetry has no packet sizes);
        # Bx >> Br keeps the eq. (3)/(6) corrections negligible.
        self.cfg = ccp_mod.CCPConfig(Bx=1e6, Br=8.0, Back=1.0, alpha=self.alpha)
        self.state = ccp_mod.init_state(self.n_workers)
        self._work = np.ones(self.n_workers)
        self._clock = np.zeros(self.n_workers)

    # -- telemetry ---------------------------------------------------------

    def observe_step(self, durations: Sequence[float],
                     rtts: Optional[Sequence[float]] = None) -> None:
        """Feed one step's per-worker wall times (seconds).  ``durations[i]``
        covers ``self._work[i]`` units of work; the estimator sees synthetic
        (Tx, Tr) pairs on a virtual clock — per-unit estimates come out via
        eq. (5)'s busy-time normalization."""
        d = np.asarray(durations, dtype=np.float64)
        units = np.maximum(self._work, 1)
        per_unit = d / units
        rtt = np.asarray(rtts if rtts is not None else np.full_like(d, 1e-4))
        finite = np.isfinite(d)
        pu = np.where(finite, per_unit, 0.0)
        # Each worker lives on its own busy-time clock: one "packet" = one
        # unit of work sent at tx=clock_n and returned at clock_n + per-unit
        # time (+rtt), so eq. (5)'s busy-time normalization yields the
        # per-unit cost estimate directly.
        tx = jnp.asarray(self._clock)
        tr = jnp.asarray(self._clock + pu + rtt)
        tr_prev = jnp.asarray(self._clock)
        active = jnp.asarray(finite)
        self.state, _ = ccp_mod.on_computed(
            self.state, self.cfg, tx, tr, tr_prev,
            jnp.asarray(rtt), active,
        )
        timed_out = jnp.asarray(~finite)
        if bool(timed_out.any()):
            self.state = ccp_mod.on_timeout(self.state, timed_out)
        self._clock = self._clock + pu

    # -- decisions ---------------------------------------------------------

    @property
    def e_beta(self) -> np.ndarray:
        e = np.asarray(self.state.e_beta, dtype=np.float64)
        backoff = np.asarray(self.state.tti_backoff, dtype=np.float64)
        e = np.where(e <= 0, np.nanmean(e[e > 0]) if (e > 0).any() else 1.0, e)
        return e * backoff  # backoff inflates the effective cost (Alg.1 l.13)

    def allocation(self, total_units: int) -> np.ndarray:
        """eq. (23): units_n proportional to 1/E[beta_n]; integers summing to
        total_units.  Dead workers get 0."""
        e = self.e_beta
        alive = ~self.dead_mask()
        inv = np.where(alive, 1.0 / e, 0.0)
        if inv.sum() == 0:
            inv = np.ones(self.n_workers)
        loads = total_units * inv / inv.sum()
        out = theory.largest_remainder_round(loads, total_units)
        self._work = np.maximum(out, 1)
        return out

    def dead_mask(self) -> np.ndarray:
        return np.asarray(self.state.tti_backoff) >= 2.0 ** self.drop_after

    def timeout_deadline(self) -> np.ndarray:
        """Per-worker step deadline (Alg. 1 l.14): 2*(TTI + RTT)."""
        e = self.e_beta * np.maximum(self._work, 1)
        rtt = np.asarray(self.state.rtt_data)
        return 2.0 * (e + rtt)
