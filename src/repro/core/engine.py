"""Policy-driven simulation engine: one entry point for every offloading
policy.

The engine owns the *scenario dynamics* — helper draws, per-packet
link/compute timing, and the churn loss processes (phase outages,
Gilbert–Elliott burst loss, correlated cell outages, slowdowns) — and
threads a :class:`~repro.core.policies.base.Policy` through the per-packet
``lax.scan``: the policy decides pacing (``next_load``), receipt handling
(``on_computed``), loss reaction (``on_timeout``) and the completion rule
(``finalize``).  Because the policy hooks are pure jnp functions, every
registered policy — including the block baselines and the adaptive
code-rate policy — runs jitted, vmapped over Monte-Carlo reps, and
device-sharded through the exact same code path.

Typical usage::

    from repro.core import engine, policies, simulator

    eng = engine.Engine()
    keys = simulator.batch_keys(reps=40)
    res = eng.run(cfg, "adaptive_rate", keys, R=2000)   # name or Policy
    res.T, res.efficiency, res.valid                    # RunResult pytree

The PR-2 string-dispatch surface (``simulator.run_batch(mode=...)``,
``run_ccp/best/naive/naive_oracle``, ``simulate_stream(mode=...)``) was
removed in PR 4; the golden tests in ``tests/test_policies.py`` still pin
``Engine.run`` bit-for-bit against its recorded outputs.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import ccp as ccp_mod
from . import decode as decode_mod
from . import policies as policies_mod
from . import simulator as sim

__all__ = ["Engine", "RunResult", "policy_stream"]


def _as_policy(policy) -> policies_mod.Policy:
    if isinstance(policy, str):
        return policies_mod.get(policy)
    return policy


# ---------------------------------------------------------------------------
# The per-helper timeline scan (scenario dynamics x policy hooks)
# ---------------------------------------------------------------------------

@functools.partial(
    jax.jit, static_argnames=("policy", "cfg_static", "churn_static")
)
def policy_stream(beta, d_up, d_ack, d_down, policy, cfg_static,
                  churn_static=None, dyn=None, a=None, aux=None):
    """Simulate M packets on every helper under ``policy``.

    Returns ``(outs, psummary)``: ``outs`` is the dict of (N, M) trace
    arrays (tr, idle, tx, arrive, beta, lost, backoff) plus ``tx_end``
    (N,) — the send time of the first unsimulated packet — and
    ``psummary`` is ``policy.summary(final_state)``.

    cfg_static: hashable (Bx, Br, Back, alpha) tuple.
    churn_static: ``ChurnConfig.static_key()`` — hashable (period,
        max_backoff, outage_dist, ge_enabled, cell_enabled) — or the
        legacy (period, max_backoff) 2-tuple (phase outages only), or
        None for the static paper model.  When set, ``dyn`` (from
        :func:`repro.core.simulator.draw_dynamics`) and ``a`` (N,)
        runtime offsets must be provided.
    aux: ``policy.prepare()`` output (per-rep traced pytree).
    """
    Bx, Br, Back, alpha = cfg_static
    cfg = ccp_mod.CCPConfig(Bx=Bx, Br=Br, Back=Back, alpha=alpha)
    N, M = beta.shape
    aux = {} if aux is None else aux
    churn = churn_static is not None
    ge_on = cell_on = False
    outage_dist = "phase"
    max_backoff = None
    if churn:
        if len(churn_static) == 2:  # legacy direct callers (phase model)
            period, max_backoff = churn_static
        else:
            period, max_backoff, outage_dist, ge_on, cell_on = churn_static
        window = period * dyn["speed"].shape[1]

    use_dec = bool(policy.uses_decoder)
    carry0 = dict(
        tx=jnp.zeros(N),              # send time of current packet (Tx_{n,1}=0)
        done_prev=jnp.zeros(N),
        tr_prev=jnp.zeros(N),
        pstate=policy.init(N),
    )
    if use_dec:
        # Incremental peeling decoder riding the scan carry: prepare() puts
        # the parity-pool tables + zero state under aux["decoder"].
        carry0["dec"] = aux["decoder"]["state0"]
        carry0["dec_t_hi"] = jnp.float32(0.0)   # max received tr so far
        carry0["dec_t_done"] = jnp.float32(jnp.inf)  # t_hi when done fired
    xs = dict(
        beta=beta.T, d_up=d_up.T, d_ack=d_ack.T, d_down=d_down.T,
        i=jnp.arange(M),
    )
    if churn:
        xs["drop"] = dyn["drop"].T
    if ge_on:
        carry0["ge_bad"] = dyn["ge_bad0"]
        xs["ge_u_trans"] = dyn["ge_u_trans"].T
        xs["ge_u_loss"] = dyn["ge_u_loss"].T

    def step(carry, x):
        tx = carry["tx"]
        # A policy may stop a helper's stream by emitting tx = +inf
        # (permanent: decoder-feedback policies stop once decode succeeds).
        # Unsent packets are non-events: no loss, no idle, no receipt —
        # churn lookups run on clamped times so no inf reaches an index op.
        sent = jnp.isfinite(tx)
        arrive = tx + x["d_up"]
        start = jnp.maximum(arrive, carry["done_prev"])
        t_arr = jnp.where(sent, arrive, 0.0)
        t_sta = jnp.where(sent, start, 0.0)
        if churn:
            # Outage if the helper is down when the packet arrives or when
            # it would start computing; degraded phases stretch the runtime
            # (beta = a + eps/mu, so (beta-a)/speed rescales the random part).
            if outage_dist == "phase":
                is_up = (sim._phase_lookup(dyn["up"], t_arr, period)
                         & sim._phase_lookup(dyn["up"], t_sta, period))
            else:
                is_up = ~(sim._interval_hit(dyn["out_start"], dyn["out_end"],
                                            t_arr, window)
                          | sim._interval_hit(dyn["out_start"], dyn["out_end"],
                                              t_sta, window)).any(axis=1)
            if cell_on:
                in_cell = dyn["cell_mask"] & (
                    sim._interval_hit(dyn["cell_start"], dyn["cell_end"],
                                      t_arr, window)
                    | sim._interval_hit(dyn["cell_start"], dyn["cell_end"],
                                        t_sta, window)
                )
                is_up &= ~in_cell.any(axis=1)
            sp = sim._phase_lookup(dyn["speed"], t_sta, period)
            beta_i = jnp.where(sp == 1.0, x["beta"], a + (x["beta"] - a) / sp)
            lost = (x["drop"] | ~is_up) & sent
        else:
            beta_i = x["beta"]
            lost = jnp.zeros((N,), bool)
        if ge_on:
            # Gilbert–Elliott: loss by the current state, then the per-packet
            # state transition (the chain advances even for packets already
            # lost to an outage — the radio fades regardless).
            p_bad, p_good, l_good, l_bad = dyn["ge_params"]
            bad = carry["ge_bad"]
            lost |= (x["ge_u_loss"] < jnp.where(bad, l_bad, l_good)) & sent
            ge_bad_next = jnp.where(
                bad, x["ge_u_trans"] >= p_good, x["ge_u_trans"] < p_bad
            )
        received = ~lost & sent
        done_ok = start + beta_i
        tr_ok = done_ok + x["d_down"]
        # A lost packet never occupies the helper nor reaches the collector.
        done = jnp.where(lost, carry["done_prev"], done_ok)
        tr = jnp.where(received, tr_ok, jnp.inf)
        idle = jnp.where(
            received, jnp.maximum(arrive - carry["done_prev"], 0.0), 0.0
        )
        rtt_ack = x["d_up"] + x["d_ack"]

        if use_dec:
            # Absorb this step's result arrivals into the peeling decoder
            # before the hooks run: the feedback a policy sees at step i is
            # everything an eagerly-decoding collector has recovered from
            # packets 0..i (see docs/policies.md for the causality note).
            dec = decode_mod.absorb(
                carry["dec"], aux["decoder"]["tables"],
                decode_mod.slot_ids(x["i"], N), received,
            )
            # Real-time bound on the decode instant: every absorbed result
            # has arrived by t_hi, so when done first fires the collector
            # provably holds a decodable set by then (StepCtx doc).
            t_hi = jnp.maximum(
                carry["dec_t_hi"], jnp.where(received, tr_ok, 0.0).max()
            )
            t_done = jnp.where(
                dec["done"] & ~jnp.isfinite(carry["dec_t_done"]),
                t_hi, carry["dec_t_done"],
            )
            dec_kw = dict(decoded_count=dec["count"], ripple=dec["ripple"],
                          decode_done=dec["done"], decode_t_done=t_done)
        else:
            dec = None
            dec_kw = {}

        ctx = policies_mod.StepCtx(
            i=x["i"], n=N, tx=tx, arrive=arrive, start=start, beta=beta_i,
            tr_ok=tr_ok, lost=lost, received=received, rtt_ack=rtt_ack,
            d_up=x["d_up"], d_down=x["d_down"], d_ack=x["d_ack"],
            tr_prev=carry["tr_prev"], cfg=cfg, max_backoff=max_backoff,
            aux=aux, **dec_kw,
        )
        pstate = policy.on_computed(carry["pstate"], ctx)
        tx_next = policy.next_load(pstate, ctx)
        if churn:
            pstate, tx_retx = policy.on_timeout(pstate, ctx, tx_next)
            tx_next = jnp.where(lost, tx_retx, tx_next)

        new_carry = dict(
            tx=tx_next, done_prev=done,
            tr_prev=jnp.where(received, tr_ok, carry["tr_prev"]),
            pstate=pstate,
        )
        if ge_on:
            new_carry["ge_bad"] = ge_bad_next
        if use_dec:
            new_carry["dec"] = dec
            new_carry["dec_t_hi"] = t_hi
            new_carry["dec_t_done"] = t_done
        b = policy.backoff(pstate)
        out = dict(tr=tr, idle=idle, tx=tx, arrive=arrive,
                   beta=jnp.where(sent, beta_i, 0.0), lost=lost,
                   backoff=b if b is not None else jnp.ones(N))
        return new_carry, out

    final, outs = jax.lax.scan(step, carry0, xs)
    res = {k: v.T for k, v in outs.items()}  # (N, M)
    res["tx_end"] = final["tx"]
    psum = policy.summary(final["pstate"])
    if use_dec:
        # Surface the end-of-horizon decoder state next to the policy's own
        # summary scalars (-> RunResult.extras dec_count / dec_done).
        psum = dict(psum, dec_count=final["dec"]["count"],
                    dec_done=final["dec"]["done"])
    return res, psum


# ---------------------------------------------------------------------------
# One Monte-Carlo rep (pure-jax core shared by the sequential, vmapped and
# sharded runners)
# ---------------------------------------------------------------------------

def _sim_one(key, cfg, R: int, M: int, policy) -> Dict[str, jnp.ndarray]:
    """Full single-rep pipeline as a traceable function of ``key``."""
    k_h, k_p = jax.random.split(key)
    mu, a, rate = sim.draw_helpers(k_h, cfg)
    beta, d_up, d_ack, d_down = sim.draw_packet_tables(
        k_p, cfg, mu, a, rate, M, R)
    c = cfg.ccp_cfg(R)
    cfg_static = (c.Bx, c.Br, c.Back, c.alpha)
    aux = policy.prepare(cfg, R, c, mu, a, rate)
    if cfg.churn is None:
        outs, psum = policy_stream(beta, d_up, d_ack, d_down, policy=policy,
                                   cfg_static=cfg_static, aux=aux)
        tx_end = None
    else:
        k_c = jax.random.fold_in(key, 0xC0DE)
        dyn = sim.draw_dynamics(k_c, cfg, M)
        outs, psum = policy_stream(
            beta, d_up, d_ack, d_down, policy=policy, cfg_static=cfg_static,
            churn_static=cfg.churn.static_key(), dyn=dyn, a=a, aux=aux,
        )
        tx_end = outs["tx_end"]
    kk = R + cfg.K(R)
    t, valid = policy.finalize(outs, aux, cfg, R, kk, tx_end)
    mask = policy.packet_mask(aux, cfg.N, M)
    if mask is None:
        tr_eff, idle_eff, beta_eff = outs["tr"], outs["idle"], outs["beta"]
    else:
        # Block policies: packets beyond the assigned block do not exist
        # physically — exclude them from the per-helper statistics.
        tr_eff = jnp.where(mask, outs["tr"], jnp.inf)
        idle_eff = jnp.where(mask, outs["idle"], 0.0)
        beta_eff = jnp.where(mask, outs["beta"], 0.0)
    eff = sim.efficiency_measured(tr_eff, idle_eff, beta_eff, t)
    # isfinite guard: when t is +inf (an uncompletable block-policy rep)
    # the inf sentinels in tr_eff must not count as delivered packets.
    r_n = (jnp.isfinite(tr_eff) & (tr_eff <= t)).sum(axis=1)
    max_backoff = outs["backoff"].max(axis=1)
    # Loss rate over packets actually *sent*: a decoder-feedback policy that
    # stops a stream early must not have its never-sent tail slots (lost =
    # False by construction) dilute the reported rate.  Expressed as a
    # rescale of mean() so always-sending policies (n_sent == M, scale
    # exactly 1.0) stay bit-identical to the pre-PR-4 goldens.
    n_sent = jnp.isfinite(outs["tx"]).sum(axis=1)
    m_steps = outs["lost"].shape[1]
    lost_frac = outs["lost"].mean(axis=1) * (
        m_steps / jnp.maximum(n_sent, 1))
    res = dict(T=t, valid=valid, efficiency=eff, r_n=r_n, mu=mu, a=a,
               rate=rate, max_backoff=max_backoff, lost_frac=lost_frac)
    for k in getattr(policy, "report_aux", ()):
        res[f"x_{k}"] = aux[k]
    for k, v in psum.items():
        res[f"x_{k}"] = v
    return res


@functools.partial(jax.jit, static_argnames=("cfg", "R", "M", "policy"))
def _sim_one_jit(key, cfg, R, M, policy):
    return _sim_one(key, cfg, R, M, policy)


@functools.partial(jax.jit, static_argnames=("cfg", "R", "M", "policy"))
def _sim_batch_jit(keys, cfg, R, M, policy):
    return jax.vmap(lambda k: _sim_one(k, cfg, R, M, policy))(keys)


@functools.lru_cache(maxsize=None)
def _sharded_batch_fn(cfg, R: int, M: int, policy, devs: tuple, batch: int):
    """Jitted shard_map runner: the key batch is split over a 1-D 'data'
    mesh of ``devs`` and each device vmaps its shard through ``_sim_one``
    — per-rep lanes are independent, so no collectives and results are
    identical to the single-device vmap."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec

    from ..parallel import sharding as shd

    mesh = shd.data_mesh(devs)
    spec = shd.batch_spec(mesh, batch, extra_dims=1)
    body = lambda k: jax.vmap(lambda kk: _sim_one(kk, cfg, R, M, policy))(k)
    fn = shard_map(body, mesh=mesh, in_specs=(spec,),
                   out_specs=PartitionSpec("data"), check_rep=False)
    return jax.jit(fn)


def _sim_batch_sharded(keys, cfg, R: int, M: int, policy, devices=None):
    """Device-sharded batch: pad the key batch to a multiple of the device
    count (padding reps are discarded after the run) and shard it over the
    local device mesh."""
    devs = tuple(devices) if devices is not None else tuple(jax.local_devices())
    B = keys.shape[0]
    pad = (-B) % len(devs)
    keys_p = keys if pad == 0 else jnp.concatenate(
        [keys, jnp.broadcast_to(keys[-1:], (pad,) + keys.shape[1:])]
    )
    out = _sharded_batch_fn(cfg, R, M, policy, devs, keys_p.shape[0])(keys_p)
    return {k: v[:B] for k, v in out.items()}


def _m_cap(cfg, kk: int, policy) -> int:
    # Static: every helper streams back-to-back, so M = R+K always
    # certifies.  Under churn a helper's M packets can include losses;
    # block policies must cover the largest assigned block — leave headroom.
    factor = policy.m_cap_factor
    if factor is None:
        factor = 1 if cfg.churn is None else 4
    return factor * kk


def _initial_m(base_m: int, cfg, R: int, kk: int, cap: int, policy,
               M_override: Optional[int]) -> int:
    """Starting horizon shared by the batched and sequential runners: the
    engine heuristic ``base_m``, clamped by the policy's ``horizon_hint``
    (block policies: ~R/N packets) and the cap.  Certification doubling
    backstops a hint that guessed low."""
    if M_override is not None:
        return min(M_override, cap)
    m = base_m
    hint = policy.horizon_hint(cfg, R, kk)
    if hint is not None:
        m = min(m, max(int(hint), 32))
    return min(m, cap)


# ---------------------------------------------------------------------------
# RunResult + Engine
# ---------------------------------------------------------------------------

_CORE_FIELDS = ("T", "valid", "efficiency", "r_n", "mu", "a", "rate",
                "max_backoff", "lost_frac")


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=list(_CORE_FIELDS) + ["extras"],
    meta_fields=["M", "policy"],
)
@dataclasses.dataclass
class RunResult:
    """Structured result of ``Engine.run`` over a key batch of B reps.

    T (B,) completion times; valid (B,) certification mask (False: the
    horizon cap was hit before the completion time could be certified —
    the rep MUST be dropped and counted, never averaged); efficiency /
    r_n / mu / a / rate / max_backoff / lost_frac (B, N) per-helper
    statistics; M the shared horizon actually used; policy the registry
    name; extras the policy trace (e.g. ``loads`` for the block
    baselines, ``p_hat`` for ``adaptive_rate``).
    """

    T: np.ndarray
    valid: np.ndarray
    efficiency: np.ndarray
    r_n: np.ndarray
    mu: np.ndarray
    a: np.ndarray
    rate: np.ndarray
    max_backoff: np.ndarray
    lost_frac: np.ndarray
    extras: Dict[str, np.ndarray]
    M: int
    policy: str

    # dict-style access keeps dict-shaped consumers (the shared benchmark
    # helpers) working on either representation.
    def __getitem__(self, key):
        d = self.as_dict()
        return d[key]

    def keys(self):
        return self.as_dict().keys()

    def as_dict(self) -> Dict[str, np.ndarray]:
        d = {f: getattr(self, f) for f in _CORE_FIELDS}
        d.update(self.extras)
        d["M"] = self.M
        return d


class Engine:
    """Single entry point for policy-driven Monte-Carlo simulation.

    ``Engine.run(cfg, policy, keys, R)`` vmaps the whole per-rep pipeline
    (helper draw -> packet tables -> policy-driven stream scan -> policy
    completion rule) over a batch of PRNG keys with one shared,
    power-of-two-bucketed horizon M and a single certification pass: if
    any rep is uncertified the shared horizon doubles and the whole batch
    re-runs (one extra compile, amortized across the sweep).  With
    ``shard=True`` the key batch is additionally split across the local
    devices through ``shard_map`` on a 1-D 'data' mesh (padded to a
    device-count multiple); per-rep lanes never communicate, so sharded
    results are bitwise identical to the unsharded vmap.
    """

    def __init__(self, shard: bool = False, devices=None):
        self.shard = shard
        self.devices = devices

    def run(self, cfg, policy, keys, R: int, *,
            M_override: Optional[int] = None,
            shard: Optional[bool] = None, devices=None) -> RunResult:
        """Run ``policy`` (a registry name or Policy instance) over a key
        batch; returns a :class:`RunResult`."""
        policy = _as_policy(policy)
        shard = self.shard if shard is None else shard
        devices = self.devices if devices is None else devices
        keys = jnp.asarray(keys)
        kk = R + cfg.K(R)
        cap = _m_cap(cfg, kk, policy)
        M = _initial_m(sim._horizon_shared(cfg, R), cfg, R, kk, cap, policy,
                       M_override)
        for _ in range(8):
            if shard:
                out = _sim_batch_sharded(keys, cfg, R, M, policy, devices)
            else:
                out = _sim_batch_jit(keys, cfg, R, M, policy)
            if bool(out["valid"].all()) or M >= cap or M_override is not None:
                break
            M = min(M * 2, cap)
        res = {k: np.asarray(v) for k, v in out.items()}
        extras = {k[2:]: v for k, v in res.items() if k.startswith("x_")}
        core = {k: v for k, v in res.items() if not k.startswith("x_")}
        return RunResult(M=M, policy=policy.name, extras=extras, **core)

    def run_one(self, key, cfg, policy, R: int, *,
                M_override: Optional[int] = None) -> Dict[str, np.ndarray]:
        """Sequential single-rep runner (grows the horizon per draw);
        mirrors the legacy ``simulator._run_mode`` contract."""
        policy = _as_policy(policy)
        k_h, _ = jax.random.split(key)
        mu, a, _rate = sim.draw_helpers(k_h, cfg)
        kk = R + cfg.K(R)
        cap = _m_cap(cfg, kk, policy)
        M = _initial_m(sim._horizon(cfg, mu, a, R), cfg, R, kk, cap, policy,
                       M_override)
        for _ in range(8):  # grow horizon until completion is certified
            out = _sim_one_jit(key, cfg, R, M, policy)
            if bool(out["valid"]) or M >= cap or M_override is not None:
                break
            M = min(M * 2, cap)
        res = {k: np.asarray(v) for k, v in out.items()}
        res["T"] = float(res["T"])
        res["M"] = M
        return res
