"""Policy-driven simulation engine: one entry point for every offloading
policy.

The engine owns the *scenario dynamics* — helper draws, per-packet
link/compute timing, and the churn loss processes (phase outages,
Gilbert–Elliott burst loss, correlated cell outages, slowdowns) — and
threads a :class:`~repro.core.policies.base.Policy` through the per-packet
``lax.scan``: the policy decides pacing (``next_load``), receipt handling
(``on_computed``), loss reaction (``on_timeout``) and the completion rule
(``finalize``).  Because the policy hooks are pure jnp functions, every
registered policy — including the block baselines and the adaptive
code-rate policy — runs jitted, vmapped over Monte-Carlo reps, and
device-sharded through the exact same code path.

Typical usage::

    from repro.core import engine, policies, simulator

    eng = engine.Engine()
    keys = simulator.batch_keys(reps=40)
    res = eng.run(cfg, "adaptive_rate", keys, R=2000)   # name or Policy
    res.T, res.efficiency, res.valid                    # RunResult pytree

The PR-2 string-dispatch surface (``simulator.run_batch(mode=...)``,
``run_ccp/best/naive/naive_oracle``, ``simulate_stream(mode=...)``) was
removed in PR 4; the golden tests in ``tests/test_policies.py`` still pin
``Engine.run`` bit-for-bit against its recorded outputs.

PR 7 factored the scan step into shared kernels (``_churn_step`` /
``_ge_step`` / ``_decode_step`` / ``_hook_step``) so the multi-tenant
event-clock scan of :mod:`repro.core.fleet` runs the exact same per-stream
ops with helper busy-time serialized across tenants;
``Engine.run_fleet(cfg, policy, keys, R, fleet=FleetConfig(...))`` is the
fleet entry point (see docs/fleet.md).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import ccp as ccp_mod
from . import decode as decode_mod
from . import policies as policies_mod
from . import simulator as sim
from . import transport as transport_mod

__all__ = ["Engine", "RunResult", "FleetRunResult", "policy_stream"]


def _as_policy(policy) -> policies_mod.Policy:
    if isinstance(policy, str):
        return policies_mod.get(policy)  # unknown names raise with known list
    if not isinstance(policy, policies_mod.Policy):
        raise TypeError(
            "policy must be a registry name or a Policy instance, got "
            f"{type(policy).__name__}; known policies: "
            f"{list(policies_mod.names())}"
        )
    return policy


def _check_inputs(keys, R):
    """Actionable validation for the public runners: an empty key batch or
    a non-positive R otherwise surfaces as an opaque scan/shape error deep
    inside jit."""
    if isinstance(R, bool) or not isinstance(R, (int, np.integer)) or R <= 0:
        raise ValueError(
            f"R must be a positive int (source packets per task), got {R!r}"
        )
    keys = jnp.asarray(keys)
    if keys.ndim == 0 or keys.shape[0] == 0:
        raise ValueError(
            "keys must be a non-empty batch of PRNG keys — e.g. "
            f"simulator.batch_keys(reps) — got shape {tuple(keys.shape)}"
        )
    typed = hasattr(jax.dtypes, "prng_key") and jnp.issubdtype(
        keys.dtype, jax.dtypes.prng_key)
    if not (typed and keys.ndim == 1) and not (
            keys.ndim == 2 and keys.shape[-1] == 2):
        raise ValueError(
            "keys must be raw PRNG keys shaped (reps, 2) "
            "(simulator.batch_keys) or a 1-D typed key array; got shape "
            f"{tuple(keys.shape)} dtype {keys.dtype}"
        )
    return keys


# ---------------------------------------------------------------------------
# Shared step kernels
#
# The per-step physics — churn evaluation, the Gilbert–Elliott chain, the
# incremental decoder absorb, and the policy-hook round — are factored out
# of ``policy_stream``'s step so the multi-tenant event-clock scan
# (:mod:`repro.core.fleet.stream`) composes the *same traced ops* per
# (task, helper) stream.  That is what makes the 1-task dedicated-pool
# fleet bit-for-bit equal to the single-task path (tests/test_fleet.py).
# ---------------------------------------------------------------------------

def _parse_churn_static(churn_static):
    """Unpack ``ChurnConfig.static_key()`` — the current 6-tuple, the
    pre-transport 5-tuple, or the legacy 2-tuple (phase outages only)
    used by direct ``policy_stream`` callers."""
    ge_on = cell_on = False
    outage_dist = "phase"
    rtt_dist = "off"
    if len(churn_static) == 2:
        period, max_backoff = churn_static
    elif len(churn_static) == 5:
        period, max_backoff, outage_dist, ge_on, cell_on = churn_static
    else:
        (period, max_backoff, outage_dist, ge_on, cell_on,
         rtt_dist) = churn_static
    return period, max_backoff, outage_dist, ge_on, cell_on, rtt_dist


def _churn_step(dyn, a, beta_x, drop, t_arr, t_sta, sent, *, period, window,
                outage_dist, cell_on):
    """Outage / slowdown / iid-drop evaluation for one step's (N,) packets.

    Outage if the helper is down when the packet arrives or when it would
    start computing; degraded phases stretch the runtime (beta = a + eps/mu,
    so (beta - a)/speed rescales the random part).  ``t_arr``/``t_sta``
    must be pre-clamped for unsent slots so no inf reaches an index op.
    """
    if outage_dist == "phase":
        is_up = (sim._phase_lookup(dyn["up"], t_arr, period)
                 & sim._phase_lookup(dyn["up"], t_sta, period))
    else:
        is_up = ~(sim._interval_hit(dyn["out_start"], dyn["out_end"],
                                    t_arr, window)
                  | sim._interval_hit(dyn["out_start"], dyn["out_end"],
                                      t_sta, window)).any(axis=1)
    if cell_on:
        in_cell = dyn["cell_mask"] & (
            sim._interval_hit(dyn["cell_start"], dyn["cell_end"],
                              t_arr, window)
            | sim._interval_hit(dyn["cell_start"], dyn["cell_end"],
                                t_sta, window)
        )
        is_up &= ~in_cell.any(axis=1)
    sp = sim._phase_lookup(dyn["speed"], t_sta, period)
    beta_i = jnp.where(sp == 1.0, beta_x, a + (beta_x - a) / sp)
    lost = (drop | ~is_up) & sent
    return beta_i, lost


def _ge_step(bad, ge_params, u_trans, u_loss, sent):
    """Gilbert–Elliott: loss by the current state, then the per-packet state
    transition (the chain advances even for packets already lost to an
    outage — the radio fades regardless).  ``u_loss``/``sent`` may carry a
    leading tenant axis (fleet: one shared chain per helper, per-tenant
    loss draws); ``bad``/``u_trans`` stay (N,)."""
    p_bad, p_good, l_good, l_bad = ge_params
    lost = (u_loss < jnp.where(bad, l_bad, l_good)) & sent
    bad_next = jnp.where(bad, u_trans >= p_good, u_trans < p_bad)
    return lost, bad_next


def _transport_step(dyn, x, ge_bad):
    """Observation delay of this step's feedback (transport layer on):
    the sampled feedback RTT, doubled when the ACK is lost — composed
    with the same GE chain state that governs this step's data loss.
    ``ge_bad`` is None when the GE chain is off.  Broadcasts over a
    leading tenant axis in ``x`` (the fleet scan)."""
    return transport_mod.observation_delay(
        dyn["rtt_base"] * x["rtt_jit"], x["ack_u"], dyn["ack_p_drop"],
        ge_bad=ge_bad, ge_params=dyn.get("ge_params"))


def _send_time_ids(sym_next, tx, sent):
    """Send-time coded-symbol assignment: rank this step's sends by their
    send instant (ties -> helper index, i.e. the legacy round-robin order)
    and hand out the next unissued global ids in that order, so a slow
    helper never sits on an early systematic id while fast helpers burn
    parities.  Unsent slots consume nothing; their placeholder ids are
    never absorbed (received=False) and never finish (tr=inf), so they
    cannot enter a decode prefix."""
    order = jnp.argsort(jnp.where(sent, tx, jnp.inf))
    rank = jnp.argsort(order).astype(jnp.int32)
    return sym_next + rank, sym_next + sent.sum(dtype=jnp.int32)


def _decode_step(dec, t_hi, t_done, tables, ids, received, tr_ok):
    """Absorb this step's result arrivals into the peeling decoder and
    maintain the real-time decode bound: every absorbed result has arrived
    by ``t_hi``, so when ``done`` first fires the collector provably holds
    a decodable set by then (StepCtx doc)."""
    dec = decode_mod.absorb(dec, tables, ids, received)
    t_hi = jnp.maximum(t_hi, jnp.where(received, tr_ok, 0.0).max())
    t_done = jnp.where(dec["done"] & ~jnp.isfinite(t_done), t_hi, t_done)
    return dec, t_hi, t_done


def _hook_step(policy, pstate, ctx, churn: bool):
    """One policy-hook round: receipt handling, pacing, and — under churn —
    the loss reaction, applied as ``where(lost, tx_retx, tx_next)``."""
    pstate = policy.on_computed(pstate, ctx)
    tx_next = policy.next_load(pstate, ctx)
    if churn:
        pstate, tx_retx = policy.on_timeout(pstate, ctx, tx_next)
        tx_next = jnp.where(ctx.lost, tx_retx, tx_next)
    b = policy.backoff(pstate)
    return pstate, tx_next, b if b is not None else jnp.ones(ctx.n)


# ---------------------------------------------------------------------------
# The per-helper timeline scan (scenario dynamics x policy hooks)
# ---------------------------------------------------------------------------

@functools.partial(
    jax.jit, static_argnames=("policy", "cfg_static", "churn_static")
)
def policy_stream(beta, d_up, d_ack, d_down, policy, cfg_static,
                  churn_static=None, dyn=None, a=None, aux=None):
    """Simulate M packets on every helper under ``policy``.

    Returns ``(outs, psummary)``: ``outs`` is the dict of (N, M) trace
    arrays (tr, idle, tx, arrive, beta, lost, backoff, and — for
    decoder-in-the-loop policies — ``sym_id``, the global coded id each
    send slot carried under the send-time assignment) plus ``tx_end``
    (N,) — the send time of the first unsimulated packet — and
    ``psummary`` is ``policy.summary(final_state)``.

    cfg_static: hashable (Bx, Br, Back, alpha) tuple.
    churn_static: ``ChurnConfig.static_key()`` — hashable (period,
        max_backoff, outage_dist, ge_enabled, cell_enabled, rtt_dist) —
        or the pre-transport 5-tuple / legacy (period, max_backoff)
        2-tuple (phase outages only), or None for the static paper
        model.  When set, ``dyn`` (from
        :func:`repro.core.simulator.draw_dynamics`) and ``a`` (N,)
        runtime offsets must be provided.  A ``rtt_dist != 'off'``
        switches on the transport feedback-delay line: the policy hooks
        then see *observed* instants (``ctx.tr_ok`` / ``ctx.rtt_ack`` /
        ``ctx.tr_prev`` shifted by the sampled feedback delay, and
        ``decode_t_done`` as a master-observed bound) while the returned
        trace stays physical (docs/transport.md).
    aux: ``policy.prepare()`` output (per-rep traced pytree).
    """
    Bx, Br, Back, alpha = cfg_static
    cfg = ccp_mod.CCPConfig(Bx=Bx, Br=Br, Back=Back, alpha=alpha)
    N, M = beta.shape
    aux = {} if aux is None else aux
    churn = churn_static is not None
    ge_on = cell_on = False
    outage_dist = "phase"
    rtt_dist = "off"
    max_backoff = None
    if churn:
        (period, max_backoff, outage_dist, ge_on,
         cell_on, rtt_dist) = _parse_churn_static(churn_static)
        window = period * dyn["speed"].shape[1]
    rtt_on = rtt_dist != "off"

    use_dec = bool(policy.uses_decoder)
    carry0 = dict(
        tx=jnp.zeros(N),              # send time of current packet (Tx_{n,1}=0)
        done_prev=jnp.zeros(N),
        tr_prev=jnp.zeros(N),
        pstate=policy.init(N),
    )
    if use_dec:
        # Incremental peeling decoder riding the scan carry: prepare() puts
        # the parity-pool tables + zero state under aux["decoder"].
        carry0["dec"] = aux["decoder"]["state0"]
        carry0["dec_t_hi"] = jnp.float32(0.0)   # max received tr so far
        carry0["dec_t_done"] = jnp.float32(jnp.inf)  # t_hi when done fired
        carry0["sym_next"] = jnp.int32(0)       # next unissued coded id
    xs = dict(
        beta=beta.T, d_up=d_up.T, d_ack=d_ack.T, d_down=d_down.T,
        i=jnp.arange(M),
    )
    if churn:
        xs["drop"] = dyn["drop"].T
    if ge_on:
        carry0["ge_bad"] = dyn["ge_bad0"]
        xs["ge_u_trans"] = dyn["ge_u_trans"].T
        xs["ge_u_loss"] = dyn["ge_u_loss"].T
    if rtt_on:
        xs["rtt_jit"] = dyn["rtt_jit"].T
        xs["ack_u"] = dyn["ack_u"].T

    def step(carry, x):
        tx = carry["tx"]
        # A policy may stop a helper's stream by emitting tx = +inf
        # (permanent: decoder-feedback policies stop once decode succeeds).
        # Unsent packets are non-events: no loss, no idle, no receipt —
        # churn lookups run on clamped times so no inf reaches an index op.
        sent = jnp.isfinite(tx)
        arrive = tx + x["d_up"]
        start = jnp.maximum(arrive, carry["done_prev"])
        t_arr = jnp.where(sent, arrive, 0.0)
        t_sta = jnp.where(sent, start, 0.0)
        if churn:
            beta_i, lost = _churn_step(
                dyn, a, x["beta"], x["drop"], t_arr, t_sta, sent,
                period=period, window=window, outage_dist=outage_dist,
                cell_on=cell_on,
            )
        else:
            beta_i = x["beta"]
            lost = jnp.zeros((N,), bool)
        if ge_on:
            lost_ge, ge_bad_next = _ge_step(
                carry["ge_bad"], dyn["ge_params"], x["ge_u_trans"],
                x["ge_u_loss"], sent,
            )
            lost |= lost_ge
        received = ~lost & sent
        done_ok = start + beta_i
        tr_ok = done_ok + x["d_down"]
        # A lost packet never occupies the helper nor reaches the collector.
        done = jnp.where(lost, carry["done_prev"], done_ok)
        tr = jnp.where(received, tr_ok, jnp.inf)
        idle = jnp.where(
            received, jnp.maximum(arrive - carry["done_prev"], 0.0), 0.0
        )
        rtt_ack = x["d_up"] + x["d_ack"]

        # Transport delay line (docs/transport.md): the physics above is
        # final — what follows (decoder absorb, policy hooks) runs on the
        # *observed* instants, one feedback RTT late (two when the ACK was
        # lost and NACK-retransmitted).  At rtt_mean = 0 the delay is
        # exactly 0.0, so the enabled path is bitwise the idealized scan.
        if rtt_on:
            obs_delay = _transport_step(
                dyn, x, carry["ge_bad"] if ge_on else None)
            tr_obs = tr_ok + obs_delay
            rtt_obs = rtt_ack + obs_delay
        else:
            tr_obs, rtt_obs = tr_ok, rtt_ack

        if use_dec:
            # Absorb this step's result arrivals into the peeling decoder
            # before the hooks run: the feedback a policy sees at step i is
            # everything an eagerly-decoding collector has recovered from
            # packets 0..i (see docs/policies.md for the causality note).
            # Fresh coded ids are handed out in send-time order, so early
            # (systematic) ids go to the helpers that actually send early.
            ids, sym_next = _send_time_ids(carry["sym_next"], tx, sent)
            # tr_obs, not tr_ok: decode_t_done is the master-*observed*
            # bound — the instant the controller can know the collector
            # holds a decodable set, which under transport lags the
            # physical decode by the feedback delay of the closing packet.
            dec, t_hi, t_done = _decode_step(
                carry["dec"], carry["dec_t_hi"], carry["dec_t_done"],
                aux["decoder"]["tables"], ids, received, tr_obs,
            )
            dec_kw = dict(decoded_count=dec["count"], ripple=dec["ripple"],
                          decode_done=dec["done"], decode_t_done=t_done)
        else:
            dec = None
            dec_kw = {}

        ctx = policies_mod.StepCtx(
            i=x["i"], n=N, tx=tx, arrive=arrive, start=start, beta=beta_i,
            tr_ok=tr_obs, lost=lost, received=received, rtt_ack=rtt_obs,
            d_up=x["d_up"], d_down=x["d_down"], d_ack=x["d_ack"],
            tr_prev=carry["tr_prev"], cfg=cfg, max_backoff=max_backoff,
            aux=aux, **dec_kw,
        )
        pstate, tx_next, b = _hook_step(policy, carry["pstate"], ctx, churn)

        new_carry = dict(
            tx=tx_next, done_prev=done,
            tr_prev=jnp.where(received, tr_obs, carry["tr_prev"]),
            pstate=pstate,
        )
        if ge_on:
            new_carry["ge_bad"] = ge_bad_next
        if use_dec:
            new_carry["dec"] = dec
            new_carry["dec_t_hi"] = t_hi
            new_carry["dec_t_done"] = t_done
            new_carry["sym_next"] = sym_next
        out = dict(tr=tr, idle=idle, tx=tx, arrive=arrive,
                   beta=jnp.where(sent, beta_i, 0.0), lost=lost,
                   backoff=b)
        if use_dec:
            out["sym_id"] = ids
        return new_carry, out

    final, outs = jax.lax.scan(step, carry0, xs)
    res = {k: v.T for k, v in outs.items()}  # (N, M)
    res["tx_end"] = final["tx"]
    psum = policy.summary(final["pstate"])
    if use_dec:
        # Surface the end-of-horizon decoder state next to the policy's own
        # summary scalars (-> RunResult.extras dec_count / dec_done).
        psum = dict(psum, dec_count=final["dec"]["count"],
                    dec_done=final["dec"]["done"])
    return res, psum


# ---------------------------------------------------------------------------
# One Monte-Carlo rep (pure-jax core shared by the sequential, vmapped and
# sharded runners)
# ---------------------------------------------------------------------------

def _sim_one(key, cfg, R: int, M: int, policy) -> Dict[str, jnp.ndarray]:
    """Full single-rep pipeline as a traceable function of ``key``."""
    k_h, k_p = jax.random.split(key)
    mu, a, rate = sim.draw_helpers(k_h, cfg)
    beta, d_up, d_ack, d_down = sim.draw_packet_tables(
        k_p, cfg, mu, a, rate, M, R)
    c = cfg.ccp_cfg(R)
    cfg_static = (c.Bx, c.Br, c.Back, c.alpha)
    aux = policy.prepare(cfg, R, c, mu, a, rate)
    if cfg.churn is None:
        outs, psum = policy_stream(beta, d_up, d_ack, d_down, policy=policy,
                                   cfg_static=cfg_static, aux=aux)
        tx_end = None
    else:
        k_c = jax.random.fold_in(key, 0xC0DE)
        dyn = sim.draw_dynamics(k_c, cfg, M)
        outs, psum = policy_stream(
            beta, d_up, d_ack, d_down, policy=policy, cfg_static=cfg_static,
            churn_static=cfg.churn.static_key(), dyn=dyn, a=a, aux=aux,
        )
        tx_end = outs["tx_end"]
    kk = R + cfg.K(R)
    t, valid = policy.finalize(outs, aux, cfg, R, kk, tx_end)
    mask = policy.packet_mask(aux, cfg.N, M)
    if mask is None:
        tr_eff, idle_eff, beta_eff = outs["tr"], outs["idle"], outs["beta"]
    else:
        # Block policies: packets beyond the assigned block do not exist
        # physically — exclude them from the per-helper statistics.
        tr_eff = jnp.where(mask, outs["tr"], jnp.inf)
        idle_eff = jnp.where(mask, outs["idle"], 0.0)
        beta_eff = jnp.where(mask, outs["beta"], 0.0)
    eff = sim.efficiency_measured(tr_eff, idle_eff, beta_eff, t)
    # isfinite guard: when t is +inf (an uncompletable block-policy rep)
    # the inf sentinels in tr_eff must not count as delivered packets.
    r_n = (jnp.isfinite(tr_eff) & (tr_eff <= t)).sum(axis=1)
    max_backoff = outs["backoff"].max(axis=1)
    # Loss rate over packets actually *sent*: a decoder-feedback policy that
    # stops a stream early must not have its never-sent tail slots (lost =
    # False by construction) dilute the reported rate.  Expressed as a
    # rescale of mean() so always-sending policies (n_sent == M, scale
    # exactly 1.0) stay bit-identical to the pre-PR-4 goldens.
    n_sent = jnp.isfinite(outs["tx"]).sum(axis=1)
    m_steps = outs["lost"].shape[1]
    lost_frac = outs["lost"].mean(axis=1) * (
        m_steps / jnp.maximum(n_sent, 1))
    res = dict(T=t, valid=valid, efficiency=eff, r_n=r_n, mu=mu, a=a,
               rate=rate, max_backoff=max_backoff, lost_frac=lost_frac)
    for k in getattr(policy, "report_aux", ()):
        res[f"x_{k}"] = aux[k]
    for k, v in psum.items():
        res[f"x_{k}"] = v
    return res


# ---------------------------------------------------------------------------
# One fleet Monte-Carlo rep: Tt tenants contending for cfg.N shared helpers
# through the event-clock scan (repro.core.fleet.stream).
# ---------------------------------------------------------------------------

def _fleet_one(key, cfg, R: int, M: int, policy, fleet) -> Dict[str, jnp.ndarray]:
    """Full single-rep fleet pipeline as a traceable function of ``key``.

    Mirrors ``_sim_one`` with a leading task axis: the helper draw (and the
    helper-state churn processes) are shared — the fleet contends for ONE
    pool — while packet tables and per-packet loss draws are per tenant.
    Task 0 reuses the single-task draws bit-for-bit (the equivalence spine).
    """
    from . import fleet as fleet_mod  # deferred: fleet imports the kernels above

    k_h, k_p = jax.random.split(key)
    mu, a, rate = sim.draw_helpers(k_h, cfg)
    Tt = fleet.n_tasks
    beta, d_up, d_ack, d_down = sim.draw_packet_tables_fleet(
        k_p, cfg, mu, a, rate, Tt, M, R)
    c = cfg.ccp_cfg(R)
    cfg_static = (c.Bx, c.Br, c.Back, c.alpha)
    release = fleet_mod.draw_releases(jax.random.fold_in(key, 0xF7EE), fleet)
    recruit, prio = fleet_mod.place(
        jax.random.fold_in(key, 0xAD31), fleet, cfg, mu, a, rate)
    per_task_aux = policy.fleet_aux == "per_task"
    if per_task_aux:
        # Block policies: one aux per tenant so the fixed allocation
        # lands on the tenant's recruited helpers (see Policy.prepare_fleet)
        aux = policy.prepare_fleet(cfg, R, c, mu, a, rate, recruit)
    else:
        aux = policy.prepare(cfg, R, c, mu, a, rate)
    if cfg.churn is None:
        outs, psum = fleet_mod.fleet_stream(
            beta, d_up, d_ack, d_down, release, recruit, prio,
            policy=policy, cfg_static=cfg_static,
            fleet_static=fleet.static_key(), aux=aux,
            aux_task_axis=per_task_aux)
        tx_end = None
    else:
        dyn = sim.draw_dynamics_fleet(
            jax.random.fold_in(key, 0xC0DE), cfg, M, Tt)
        outs, psum = fleet_mod.fleet_stream(
            beta, d_up, d_ack, d_down, release, recruit, prio,
            policy=policy, cfg_static=cfg_static,
            fleet_static=fleet.static_key(),
            churn_static=cfg.churn.static_key(), dyn=dyn, a=a, aux=aux,
            aux_task_axis=per_task_aux)
        tx_end = outs["tx_end"]
    kk = R + cfg.K(R)
    if per_task_aux:
        mask = jax.vmap(lambda at: policy.packet_mask(at, cfg.N, M))(aux)
    else:
        mask = policy.packet_mask(aux, cfg.N, M)
    per_keys = ("tr", "idle", "tx", "arrive", "beta", "lost", "backoff")
    if policy.uses_decoder:
        per_keys += ("sym_id",)
    task_outs = {k: outs[k] for k in per_keys}

    def _finish(outs_t, tx_end_t, aux_t, mask_t):
        # Per-task completion + per-helper statistics: the same extraction
        # as _sim_one, vmapped over the task axis (aux/mask mapped per
        # task for fleet_aux == "per_task" block policies, else shared).
        t, valid = policy.finalize(outs_t, aux_t, cfg, R, kk, tx_end_t)
        if mask_t is None:
            tr_eff, idle_eff, beta_eff = (
                outs_t["tr"], outs_t["idle"], outs_t["beta"])
        else:
            tr_eff = jnp.where(mask_t, outs_t["tr"], jnp.inf)
            idle_eff = jnp.where(mask_t, outs_t["idle"], 0.0)
            beta_eff = jnp.where(mask_t, outs_t["beta"], 0.0)
        eff = sim.efficiency_measured(tr_eff, idle_eff, beta_eff, t)
        r_n = (jnp.isfinite(tr_eff) & (tr_eff <= t)).sum(axis=1)
        n_sent = jnp.isfinite(outs_t["tx"]).sum(axis=1)
        m_steps = outs_t["lost"].shape[1]
        lost_frac = outs_t["lost"].mean(axis=1) * (
            m_steps / jnp.maximum(n_sent, 1))
        return dict(T=t, valid=valid, efficiency=eff, r_n=r_n,
                    max_backoff=outs_t["backoff"].max(axis=1),
                    lost_frac=lost_frac)

    aux_ax = 0 if per_task_aux else None
    if tx_end is None:
        res = jax.vmap(lambda o, at, mt: _finish(o, None, at, mt),
                       in_axes=(0, aux_ax, aux_ax))(task_outs, aux, mask)
    else:
        res = jax.vmap(_finish, in_axes=(0, 0, aux_ax, aux_ax))(
            task_outs, tx_end, aux, mask)
    res["release"] = release
    res["sojourn"] = res["T"] - release
    # Fleet-level metrics: helper utilization over the rep's makespan and
    # Jain fairness over the valid tenants' sojourn times.
    vmask = res["valid"] & jnp.isfinite(res["T"])
    makespan = jnp.max(jnp.where(vmask, res["T"], -jnp.inf))
    res["makespan"] = makespan
    res["util"] = fleet_mod.helper_utilization(
        outs["beta"], outs["tr"], d_down, makespan)
    res["fairness"] = fleet_mod.jain_fairness(res["sojourn"], vmask)
    res.update(mu=mu, a=a, rate=rate)
    for k in getattr(policy, "report_aux", ()):
        res[f"x_{k}"] = aux[k]
    for k, v in psum.items():
        res[f"x_{k}"] = v
    return res


@functools.partial(jax.jit, static_argnames=("cfg", "R", "M", "policy"))
def _sim_one_jit(key, cfg, R, M, policy):
    return _sim_one(key, cfg, R, M, policy)


@functools.partial(
    jax.jit, static_argnames=("cfg", "R", "M", "policy", "fleet")
)
def _fleet_batch_jit(keys, cfg, R, M, policy, fleet):
    return jax.vmap(lambda k: _fleet_one(k, cfg, R, M, policy, fleet))(keys)


@functools.partial(jax.jit, static_argnames=("cfg", "R", "M", "policy"))
def _sim_batch_jit(keys, cfg, R, M, policy):
    return jax.vmap(lambda k: _sim_one(k, cfg, R, M, policy))(keys)


@functools.lru_cache(maxsize=None)
def _sharded_batch_fn(cfg, R: int, M: int, policy, devs: tuple, batch: int):
    """Jitted shard_map runner: the key batch is split over a 1-D 'data'
    mesh of ``devs`` and each device vmaps its shard through ``_sim_one``
    — per-rep lanes are independent, so no collectives and results are
    identical to the single-device vmap."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec

    from ..parallel import sharding as shd

    mesh = shd.data_mesh(devs)
    spec = shd.batch_spec(mesh, batch, extra_dims=1)
    body = lambda k: jax.vmap(lambda kk: _sim_one(kk, cfg, R, M, policy))(k)
    fn = shard_map(body, mesh=mesh, in_specs=(spec,),
                   out_specs=PartitionSpec("data"), check_rep=False)
    return jax.jit(fn)


def _sim_batch_sharded(keys, cfg, R: int, M: int, policy, devices=None):
    """Device-sharded batch: pad the key batch to a multiple of the device
    count (padding reps are discarded after the run) and shard it over the
    local device mesh."""
    devs = tuple(devices) if devices is not None else tuple(jax.local_devices())
    B = keys.shape[0]
    pad = (-B) % len(devs)
    keys_p = keys if pad == 0 else jnp.concatenate(
        [keys, jnp.broadcast_to(keys[-1:], (pad,) + keys.shape[1:])]
    )
    out = _sharded_batch_fn(cfg, R, M, policy, devs, keys_p.shape[0])(keys_p)
    return {k: v[:B] for k, v in out.items()}


@functools.lru_cache(maxsize=None)
def _fleet_sharded_batch_fn(cfg, R: int, M: int, policy, fleet, devs: tuple,
                            batch: int):
    """Fleet twin of :func:`_sharded_batch_fn`: the key batch splits over
    the same 1-D 'data' mesh and each device vmaps its shard through
    ``_fleet_one``.  Reps are independent (every tenant of a rep lives on
    that rep's device), so there are no collectives and the sharded run
    is bitwise the single-device ``_fleet_batch_jit`` vmap."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec

    from ..parallel import sharding as shd

    mesh = shd.data_mesh(devs)
    spec = shd.batch_spec(mesh, batch, extra_dims=1)
    body = lambda k: jax.vmap(
        lambda kk: _fleet_one(kk, cfg, R, M, policy, fleet))(k)
    fn = shard_map(body, mesh=mesh, in_specs=(spec,),
                   out_specs=PartitionSpec("data"), check_rep=False)
    return jax.jit(fn)


def _fleet_batch_sharded(keys, cfg, R: int, M: int, policy, fleet,
                         devices=None):
    """Device-sharded fleet batch (pad-to-device-multiple, as in
    :func:`_sim_batch_sharded`)."""
    devs = tuple(devices) if devices is not None else tuple(jax.local_devices())
    B = keys.shape[0]
    pad = (-B) % len(devs)
    keys_p = keys if pad == 0 else jnp.concatenate(
        [keys, jnp.broadcast_to(keys[-1:], (pad,) + keys.shape[1:])]
    )
    out = _fleet_sharded_batch_fn(
        cfg, R, M, policy, fleet, devs, keys_p.shape[0])(keys_p)
    return {k: v[:B] for k, v in out.items()}


def _m_cap(cfg, kk: int, policy) -> int:
    # Static: every helper streams back-to-back, so M = R+K always
    # certifies.  Under churn a helper's M packets can include losses;
    # block policies must cover the largest assigned block — leave headroom.
    factor = policy.m_cap_factor
    if factor is None:
        factor = 1 if cfg.churn is None else 4
    return factor * kk


def _initial_m(base_m: int, cfg, R: int, kk: int, cap: int, policy,
               M_override: Optional[int]) -> int:
    """Starting horizon shared by the batched and sequential runners: the
    engine heuristic ``base_m``, clamped by the policy's ``horizon_hint``
    (block policies: ~R/N packets) and the cap.  Certification doubling
    backstops a hint that guessed low."""
    if M_override is not None:
        return min(M_override, cap)
    m = base_m
    hint = policy.horizon_hint(cfg, R, kk)
    if hint is not None:
        m = min(m, max(int(hint), 32))
    return min(m, cap)


# ---------------------------------------------------------------------------
# RunResult + Engine
# ---------------------------------------------------------------------------

_CORE_FIELDS = ("T", "valid", "efficiency", "r_n", "mu", "a", "rate",
                "max_backoff", "lost_frac")


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=list(_CORE_FIELDS) + ["extras"],
    meta_fields=["M", "policy"],
)
@dataclasses.dataclass
class RunResult:
    """Structured result of ``Engine.run`` over a key batch of B reps.

    T (B,) completion times; valid (B,) certification mask (False: the
    horizon cap was hit before the completion time could be certified —
    the rep MUST be dropped and counted, never averaged); efficiency /
    r_n / mu / a / rate / max_backoff / lost_frac (B, N) per-helper
    statistics; M the shared horizon actually used; policy the registry
    name; extras the policy trace (e.g. ``loads`` for the block
    baselines, ``p_hat`` for ``adaptive_rate``).
    """

    T: np.ndarray
    valid: np.ndarray
    efficiency: np.ndarray
    r_n: np.ndarray
    mu: np.ndarray
    a: np.ndarray
    rate: np.ndarray
    max_backoff: np.ndarray
    lost_frac: np.ndarray
    extras: Dict[str, np.ndarray]
    M: int
    policy: str

    # dict-style access keeps dict-shaped consumers (the shared benchmark
    # helpers) working on either representation.
    def __getitem__(self, key):
        d = self.as_dict()
        return d[key]

    def keys(self):
        return self.as_dict().keys()

    def as_dict(self) -> Dict[str, np.ndarray]:
        d = {f: getattr(self, f) for f in _CORE_FIELDS}
        d.update(self.extras)
        d["M"] = self.M
        return d


_FLEET_FIELDS = ("T", "sojourn", "release", "valid", "efficiency", "r_n",
                 "mu", "a", "rate", "max_backoff", "lost_frac", "util",
                 "fairness", "makespan")


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=list(_FLEET_FIELDS) + ["extras"],
    meta_fields=["M", "policy", "n_tasks", "discipline"],
)
@dataclasses.dataclass
class FleetRunResult:
    """Structured result of ``Engine.run_fleet`` over B reps of a
    ``n_tasks``-tenant fleet sharing ``cfg.N`` helpers.

    T / sojourn / release / valid: (B, n_tasks) per-task completion time
    (absolute), completion minus release, release time, and certification
    mask (an uncertified task MUST be dropped and counted, never averaged);
    efficiency / r_n / max_backoff / lost_frac: (B, n_tasks, N) per-task
    per-helper statistics; mu / a / rate: (B, N) shared helper draws; util:
    (B, N) per-helper busy fraction inside the rep's makespan; fairness:
    (B,) Jain index over the valid tasks' sojourns; makespan: (B,) last
    valid completion.  ``summary()`` reduces the batch to the scalars the
    ``fig_fleet`` sweep plots.
    """

    T: np.ndarray
    sojourn: np.ndarray
    release: np.ndarray
    valid: np.ndarray
    efficiency: np.ndarray
    r_n: np.ndarray
    mu: np.ndarray
    a: np.ndarray
    rate: np.ndarray
    max_backoff: np.ndarray
    lost_frac: np.ndarray
    util: np.ndarray
    fairness: np.ndarray
    makespan: np.ndarray
    extras: Dict[str, np.ndarray]
    M: int
    policy: str
    n_tasks: int
    discipline: str

    def __getitem__(self, key):
        return self.as_dict()[key]

    def keys(self):
        return self.as_dict().keys()

    def as_dict(self) -> Dict[str, np.ndarray]:
        d = {f: getattr(self, f) for f in _FLEET_FIELDS}
        d.update(self.extras)
        d["M"] = self.M
        return d

    def summary(self) -> Dict[str, float]:
        """Batch scalars for the saturation sweep: p50/p99 sojourn over the
        certified tasks, mean helper utilization and fairness, and the
        uncertified-task count."""
        ok = np.asarray(self.valid, bool) & np.isfinite(self.sojourn)
        soj = np.asarray(self.sojourn)[ok]
        return dict(
            p50=float(np.percentile(soj, 50)) if soj.size else float("nan"),
            p99=float(np.percentile(soj, 99)) if soj.size else float("nan"),
            util_mean=float(np.nanmean(np.asarray(self.util))),
            fairness_mean=float(np.nanmean(np.asarray(self.fairness))),
            invalid=int((~np.asarray(self.valid, bool)).sum()),
        )


class Engine:
    """Single entry point for policy-driven Monte-Carlo simulation.

    ``Engine.run(cfg, policy, keys, R)`` vmaps the whole per-rep pipeline
    (helper draw -> packet tables -> policy-driven stream scan -> policy
    completion rule) over a batch of PRNG keys with one shared,
    power-of-two-bucketed horizon M and a single certification pass: if
    any rep is uncertified the shared horizon doubles and the whole batch
    re-runs (one extra compile, amortized across the sweep).  With
    ``shard=True`` the key batch is additionally split across the local
    devices through ``shard_map`` on a 1-D 'data' mesh (padded to a
    device-count multiple); per-rep lanes never communicate, so sharded
    results are bitwise identical to the unsharded vmap.
    """

    def __init__(self, shard: bool = False, devices=None):
        self.shard = shard
        self.devices = devices

    def run(self, cfg, policy, keys, R: int, *,
            M_override: Optional[int] = None,
            shard: Optional[bool] = None, devices=None) -> RunResult:
        """Run ``policy`` (a registry name or Policy instance) over a key
        batch; returns a :class:`RunResult`."""
        policy = _as_policy(policy)
        shard = self.shard if shard is None else shard
        devices = self.devices if devices is None else devices
        keys = _check_inputs(keys, R)
        kk = R + cfg.K(R)
        cap = _m_cap(cfg, kk, policy)
        M = _initial_m(sim._horizon_shared(cfg, R), cfg, R, kk, cap, policy,
                       M_override)
        for _ in range(8):
            if shard:
                out = _sim_batch_sharded(keys, cfg, R, M, policy, devices)
            else:
                out = _sim_batch_jit(keys, cfg, R, M, policy)
            if bool(out["valid"].all()) or M >= cap or M_override is not None:
                break
            M = min(M * 2, cap)
        res = {k: np.asarray(v) for k, v in out.items()}
        extras = {k[2:]: v for k, v in res.items() if k.startswith("x_")}
        core = {k: v for k, v in res.items() if not k.startswith("x_")}
        return RunResult(M=M, policy=policy.name, extras=extras, **core)

    def run_fleet(self, cfg, policy, keys, R: int, *, fleet=None,
                  M_override: Optional[int] = None,
                  shard: Optional[bool] = None,
                  devices=None) -> FleetRunResult:
        """Multi-tenant event-clock run: ``fleet.n_tasks`` concurrent tasks
        contend for the ``cfg.N`` shared helpers under the configured
        service discipline and admission rule (see docs/fleet.md).

        ``fleet`` is a :class:`repro.core.fleet.FleetConfig` (default: one
        task, all helpers, FIFO).  At ``n_tasks=1`` with the default
        all-helpers placement the event-clock scan is bit-for-bit
        ``Engine.run`` for every registered policy — the equivalence-spine
        tests in ``tests/test_fleet.py`` pin this against the goldens.
        Certification works as in :meth:`run`: the shared horizon doubles
        until every (rep, task) completion is certified or the cap is hit.
        With ``shard=True`` (or an ``Engine(shard=True)``) the key batch
        splits over the local 'data' mesh exactly as in :meth:`run`, and
        the sharded results are bitwise the vmap path's.
        """
        from . import fleet as fleet_mod

        policy = _as_policy(policy)
        shard = self.shard if shard is None else shard
        devices = self.devices if devices is None else devices
        fleet = fleet_mod.FleetConfig() if fleet is None else fleet
        if not isinstance(fleet, fleet_mod.FleetConfig):
            raise TypeError(
                "fleet must be a repro.core.fleet.FleetConfig (or None for "
                f"the 1-task default), got {type(fleet).__name__}: {fleet!r}"
            )
        if fleet.placement not in fleet_mod.PLACEMENTS:
            raise ValueError(
                f"unknown placement {fleet.placement!r}; known: "
                f"{sorted(fleet_mod.PLACEMENTS)} (register_placement adds "
                "custom rules)"
            )
        if (fleet.helpers_per_task is not None
                and fleet.helpers_per_task > cfg.N):
            raise ValueError(
                f"helpers_per_task={fleet.helpers_per_task} exceeds the "
                f"cfg.N={cfg.N} helpers in the pool"
            )
        keys = _check_inputs(keys, R)
        kk = R + cfg.K(R)
        cap = _m_cap(cfg, kk, policy)
        M = _initial_m(sim._horizon_shared(cfg, R), cfg, R, kk, cap, policy,
                       M_override)
        for _ in range(8):
            if shard:
                out = _fleet_batch_sharded(
                    keys, cfg, R, M, policy, fleet, devices)
            else:
                out = _fleet_batch_jit(keys, cfg, R, M, policy, fleet)
            if bool(out["valid"].all()) or M >= cap or M_override is not None:
                break
            M = min(M * 2, cap)
        res = {k: np.asarray(v) for k, v in out.items()}
        extras = {k[2:]: v for k, v in res.items() if k.startswith("x_")}
        core = {k: v for k, v in res.items() if not k.startswith("x_")}
        return FleetRunResult(M=M, policy=policy.name,
                              n_tasks=fleet.n_tasks,
                              discipline=fleet.discipline,
                              extras=extras, **core)

    def run_one(self, key, cfg, policy, R: int, *,
                M_override: Optional[int] = None) -> Dict[str, np.ndarray]:
        """Sequential single-rep runner (grows the horizon per draw);
        mirrors the legacy ``simulator._run_mode`` contract."""
        policy = _as_policy(policy)
        if isinstance(R, bool) or not isinstance(R, (int, np.integer)) or R <= 0:
            raise ValueError(
                f"R must be a positive int (source packets per task), got {R!r}"
            )
        k_h, _ = jax.random.split(key)
        mu, a, _rate = sim.draw_helpers(k_h, cfg)
        kk = R + cfg.K(R)
        cap = _m_cap(cfg, kk, policy)
        M = _initial_m(sim._horizon(cfg, mu, a, R), cfg, R, kk, cap, policy,
                       M_override)
        for _ in range(8):  # grow horizon until completion is certified
            out = _sim_one_jit(key, cfg, R, M, policy)
            if bool(out["valid"]) or M >= cap or M_override is not None:
                break
            M = min(M * 2, cap)
        res = {k: np.asarray(v) for k, v in out.items()}
        res["T"] = float(res["T"])
        res["M"] = M
        return res
