"""Distributed coded matmul over the 'model' mesh axis (shard_map).

The paper's end-to-end object: y = A x computed by N workers holding
fountain-coded row-blocks, tolerant to any K worker losses.  Each device
holds a contiguous slice of the coded block space (systematic blocks +
parities interleaved round-robin so losing a device loses a *spread* of
blocks, not a contiguous run); compute is the fused Pallas kernel (or jnp
fallback); a lost device is modeled by a survivor mask and the collector
recovers y by peeling/dense decode.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..kernels.coded_matmul import coded_matmul as coded_matmul_op
from . import fountain

__all__ = ["CodedMatmulPlan", "plan_coded_matmul", "device_blocks", "run", "recover"]


@dataclasses.dataclass(frozen=True)
class CodedMatmulPlan:
    """Static plan: code + device->coded-block placement for n_shards."""

    code: fountain.LTCode
    n_shards: int
    placement: np.ndarray      # (n_shards, blocks_per_shard) coded ids
    bm: int                    # rows per block

    @property
    def blocks_per_shard(self) -> int:
        return self.placement.shape[1]


def plan_coded_matmul(
    rows: int, n_shards: int, overhead: float = 0.25, bm: int = 128,
    seed: int = 0, validate_losses: int = 1, max_tries: int = 50,
) -> CodedMatmulPlan:
    """Split an (rows x k) matrix into bm-row blocks, build a systematic LT
    code with ~``overhead`` parities rounded so every shard holds the same
    block count, and place blocks round-robin across shards.

    Placement-aware validation: on a mesh the unit of failure is a *shard*
    (a whole device's blocks at once), so the plan is rank-checked against
    every loss pattern of up to ``validate_losses`` shards and re-seeded
    until all decode — turning the fountain code's probabilistic contract
    into a deterministic per-plan guarantee (cf. Raptor pre-validation)."""
    if rows % bm:
        raise ValueError(f"rows={rows} not divisible by bm={bm}")
    R = rows // bm
    K = int(np.ceil(R * overhead))
    total = R + K
    if total % n_shards:  # pad K so shards are uniform
        K += n_shards - total % n_shards
    ids = np.arange(R + K)
    placement = np.stack([ids[s::n_shards] for s in range(n_shards)])

    import itertools

    last_err = None
    for t in range(max_tries):
        # dense ±1 parities: encode adds are VPU-cheap next to the fused
        # MXU matmul, and small-block shard-loss patterns become
        # generically full-rank (see fountain.make_lt_code docstring)
        code = fountain.make_lt_code(
            R, K, seed=seed + 7919 * t, parity_degree=max(R // 2, 4)
        )
        if validate_losses <= 0:
            return CodedMatmulPlan(code, n_shards, placement, bm)
        G = code.dense_generator()
        ok = True
        for r in range(1, validate_losses + 1):
            for lost in itertools.combinations(range(n_shards), r):
                keep = np.setdiff1d(np.arange(n_shards), lost)
                rx = placement[keep].reshape(-1)
                if np.linalg.matrix_rank(G[rx]) < R:
                    ok = False
                    break
            if not ok:
                break
        if ok:
            return CodedMatmulPlan(code, n_shards, placement, bm)
        last_err = f"seed {seed + 7919 * t} fails a {r}-shard loss pattern"
    raise ValueError(
        f"no code tolerating {validate_losses}-shard losses found in "
        f"{max_tries} tries (R={R}, K={K}, shards={n_shards}); raise the "
        f"overhead. Last: {last_err}"
    )


def device_blocks(plan: CodedMatmulPlan, a: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Gather per-device (idx, weights) tables in placement order:
    returns (idx (S*Bp, d_max), weights (S*Bp, d_max)) where row s*Bp+i is
    the i-th coded block on shard s (weights = mask * Rademacher coef)."""
    flat = plan.placement.reshape(-1)
    return (
        jnp.asarray(plan.code.idx[flat]),
        jnp.asarray(plan.code.weights[flat]),
    )


def run(
    plan: CodedMatmulPlan,
    a: jnp.ndarray,             # (rows, k_dim) source matrix
    x: jnp.ndarray,             # (k_dim, n_dim)
    mesh: Optional[Mesh] = None,
    axis: str = "model",
    use_pallas: bool = False,
    interpret: bool = False,
) -> jnp.ndarray:
    """Compute all coded block products, laid out shard-major:
    out[s*Bp+i] = (G A)[placement[s, i]] @ x, shape (S*Bp*bm, n_dim).

    With a mesh, the coded-row dim is sharded over ``axis`` via shard_map —
    each device encodes+computes only its own blocks (the paper's helpers).
    """
    idx, mask = device_blocks(plan, a)

    def local(a_full, x_full, idx_s, mask_s):
        return coded_matmul_op(
            a_full, x_full, idx_s, mask_s, bm=plan.bm,
            use_pallas=use_pallas, interpret=interpret,
        )

    if mesh is None:
        return local(a, x, idx, mask)

    from jax.experimental.shard_map import shard_map

    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(P(), P(), P(axis), P(axis)),
        out_specs=P(axis),
        check_rep=False,
    )
    return fn(a, x, idx, mask)


def recover(
    plan: CodedMatmulPlan,
    out: jnp.ndarray,           # (S*Bp*bm, n_dim) coded results
    survivors: np.ndarray,      # shard ids that returned
) -> jnp.ndarray:
    """Collector-side recovery of y = A x from surviving shards only."""
    Bp, bm = plan.blocks_per_shard, plan.bm
    rows = []
    ids = []
    for s in survivors:
        sl = out[s * Bp * bm : (s + 1) * Bp * bm]
        rows.append(sl.reshape(Bp, bm, -1))
        ids.extend(plan.placement[s].tolist())
    coded_rx = jnp.concatenate(rows, axis=0)  # (n_rx, bm, n_dim)
    dec, _ = fountain.decode(coded_rx, plan.code, np.asarray(ids))
    return dec.reshape(plan.code.R * bm, -1)
