"""Closed-form theory from the paper (Theorems 1-3 and §4-§5).

All formulas keep the paper's notation:
  beta_{n,i} ~ shifted exponential, shift a_n, rate mu_n, mean a_n + 1/mu_n.
  RTT^data_n — per-helper data round-trip time.
  R packets + K coding overhead.
"""

from __future__ import annotations

import numpy as np

__all__ = [
    "shifted_exp_mean",
    "expected_underutilization",
    "efficiency",
    "t_opt_model1",
    "t_opt_model2_realized",
    "t_opt_model2_upper",
    "optimal_allocation",
]


def shifted_exp_mean(a, mu):
    """E[beta] = a + 1/mu."""
    a = np.asarray(a, dtype=np.float64)
    mu = np.asarray(mu, dtype=np.float64)
    return a + 1.0 / mu


def expected_underutilization(rtt_data, mu):
    """Theorem 1 / eq. (11): E[Tu_{n,i}] per packet.

    E[Tu] = RTT + (1/mu)(e^{-1} - e^{mu RTT - 1})   if RTT < 1/mu
          = (1/(e mu))                              otherwise
    """
    rtt = np.asarray(rtt_data, dtype=np.float64)
    mu = np.asarray(mu, dtype=np.float64)
    small = rtt < 1.0 / mu
    e_small = rtt + (np.exp(-1.0) - np.exp(np.minimum(mu * rtt, 1.0) - 1.0)) / mu
    e_large = 1.0 / (np.e * mu)
    return np.where(small, e_small, e_large)


def efficiency(rtt_data, a, mu):
    """eq. (12): gamma_n = 1 - E[Tu_{n,i}] / E[beta_{n,i}]."""
    return 1.0 - expected_underutilization(rtt_data, mu) / shifted_exp_mean(a, mu)


def t_opt_model1(R, K, a, mu):
    """Theorem 2 / eq. (27): T_opt = (R+K) / sum_n mu_n/(1 + a_n mu_n)."""
    a = np.asarray(a, dtype=np.float64)
    mu = np.asarray(mu, dtype=np.float64)
    return (R + K) / np.sum(mu / (1.0 + a * mu))


def t_opt_model2_realized(R, K, beta):
    """Theorem 3 / eq. (29): T_opt = (R+K) / sum_n 1/beta_n for realized beta_n."""
    beta = np.asarray(beta, dtype=np.float64)
    return (R + K) / np.sum(1.0 / beta)


def t_opt_model2_upper(R, K, a, mu):
    """eq. (30): E[T_opt] <= (R+K) / sum_n mu_n/(1 + a_n mu_n)."""
    return t_opt_model1(R, K, a, mu)


def optimal_allocation(R, K, e_beta):
    """eq. (23): r_n^opt = (R+K) / (E[beta_n] * sum_m 1/E[beta_m]).

    Returns real-valued loads summing to R+K (integerize via largest
    remainder where needed).
    """
    e_beta = np.asarray(e_beta, dtype=np.float64)
    inv = 1.0 / e_beta
    return (R + K) * inv / inv.sum()


def largest_remainder_round(loads, total: int) -> np.ndarray:
    """Round non-negative real loads to ints summing exactly to ``total``."""
    loads = np.asarray(loads, dtype=np.float64)
    base = np.floor(loads).astype(np.int64)
    short = int(total - base.sum())
    if short < 0:  # defensive: loads summed above total
        order = np.argsort(loads - base)
        for i in order[: -short]:
            base[i] = max(base[i] - 1, 0)
        return base
    frac = loads - base
    order = np.argsort(-frac)
    base[order[:short]] += 1
    return base
