"""Vectorized discrete-event simulation of CCP and the paper's baselines.

Reproduces §6 of the paper: a collector offloads fountain-coded packets to
``N`` heterogeneous helpers over links with random per-packet rates; helper
``n`` computes packet ``i`` in ``beta_{n,i}`` (Scenario 1: i.i.d.
shifted-exponential per packet; Scenario 2: one draw per helper).  The
completion time is when the collector has received ``R+K`` computed packets.

Instead of a global event queue (O(N*R) sequential events), we exploit that
helpers only couple through the *stopping rule*: each helper's packet
timeline is an independent recurrence, so we

  1. scan each helper's timeline for ``M`` packets (vectorized over helpers,
     ``lax.scan`` over the packet index),
  2. merge the computed-packet arrival times ``Tr`` across helpers and take
     the (R+K)-th order statistic as the completion time.

The CCP send rule, eq. (8) ``TTI_i = min(Tr_i - Tx_i, E[beta])``, is *causal*
when read operationally:  ``tx_{i+1} = min(Tr_i, tx_i + E[beta])`` — send the
next packet either the moment the previous computed result returns (the
helper finished early) or when ``E[beta]`` has elapsed since the last send
(the cap), whichever happens first.  The ``E[beta]`` estimate in effect is
the latest one whose computed packet had returned by ``tx_i`` (held in a
small ring buffer).  Until the first computed packet returns the collector
has no estimate and falls back to stop-and-wait — this reproduces the
startup under-utilization the paper reports in §6 (Efficiency).

Timing model per packet (helper n, packet i):
  arrive_i = tx_i + d_up_i                      (uplink)
  start_i  = max(arrive_i, done_{i-1})          (FIFO helper queue)
  done_i   = start_i + beta_i
  Tr_i     = done_i + d_down_i                  (result downlink)
  RTTack_i = d_up_i + d_ack_i                   (receipt ACK, measured)
  idle_i   = max(0, arrive_i - done_{i-1})      (helper under-utilization)

Dynamics / churn (beyond the paper's static Scenarios 1-2)
----------------------------------------------------------
``ScenarioConfig.churn = ChurnConfig(...)`` switches on a piecewise-constant
time-varying resource model: time is divided into phases of ``period``
seconds (``n_phases`` distinct phases, wrapping around), and in each phase a
helper is independently *down* with prob ``p_down`` (packets sent to it are
lost) or *degraded* with prob ``p_slow`` (its service rate ``mu_n`` is
divided by ``slowdown``).  On top, each packet is lost i.i.d. with prob
``drop_prob``.  A lost packet never produces a ``Tr``; the collector reacts
with Algorithm 1 lines 13-14: the TTI backoff doubles (``ccp.on_timeout``,
capped at ``max_backoff``) and the retransmission fires at the timeout
deadline ``TO = 2*(TTI + RTT^data)`` (``ccp.timeout_deadline`` form).  A
successful receipt resets the backoff, so helpers that rejoin are re-ramped.
``churn=None`` (default) runs the exact static paper model, bit-for-bit.

Batched Monte-Carlo (``run_batch``)
-----------------------------------
``run_batch(keys, cfg, R, mode)`` vmaps the whole per-rep pipeline (helper
draw -> packet tables -> stream scan -> order statistic) over a batch of
PRNG keys with one shared, power-of-two-bucketed horizon ``M`` and a single
certification pass: if any rep's order statistic is uncertified the shared
horizon doubles and the whole batch re-runs (one extra compile, amortized
across the sweep).  Typical usage::

    keys = simulator.batch_keys(reps=40, seed0=0)
    out = simulator.run_batch(keys, cfg, R=2000, mode="ccp")
    out["T"]           # (reps,) completion times
    out["efficiency"]  # (reps, N) per-helper measured efficiency

This replaces a Python loop of ``reps`` jitted calls with one vmapped call
and is the engine behind ``benchmarks/fig3|4|5|churn``.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import ccp as ccp_mod
from . import theory

__all__ = [
    "ChurnConfig",
    "ScenarioConfig",
    "draw_helpers",
    "draw_packet_tables",
    "draw_dynamics",
    "simulate_stream",
    "completion_time",
    "batch_keys",
    "run_batch",
    "run_ccp",
    "run_best",
    "run_naive",
    "RING",
]

RING = 16  # ring-buffer slots for in-flight (Tr, TTI) pairs


# ---------------------------------------------------------------------------
# Configuration and random draws
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ChurnConfig:
    """Piecewise time-varying resource model (see module docstring).

    period:     phase length in seconds; helper states re-randomize each
                phase, so ``period`` sets the churn timescale.
    n_phases:   distinct phases drawn; the schedule wraps (mod) beyond that.
    p_down:     per-phase prob a helper is unavailable (its packets are lost).
    p_slow:     per-phase prob a helper is degraded (mu_n / slowdown).
    slowdown:   service-rate divisor while degraded.
    drop_prob:  i.i.d. per-packet loss on top of outages.
    max_backoff: cap on the Alg.-1 line-13 multiplicative TTI backoff so a
                rejoining helper is re-probed within a bounded interval.
    """

    period: float = 5.0
    n_phases: int = 16
    p_down: float = 0.0
    p_slow: float = 0.0
    slowdown: float = 4.0
    drop_prob: float = 0.0
    max_backoff: float = 8.0

    @property
    def neutral(self) -> bool:
        return self.p_down == 0.0 and self.p_slow == 0.0 and self.drop_prob == 0.0


@dataclasses.dataclass(frozen=True)
class ScenarioConfig:
    """Paper §6 simulation setup.

    scenario: 1 (i.i.d. per-packet runtimes / Model I) or
              2 (one runtime draw per helper / Model II).
    a_mode:   'const' -> a_n = a_const;  'inv_mu' -> a_n = 1/mu_n.
    mu_choices: helper speeds drawn uniformly from this set.
    rate_lo/rate_hi: per-helper mean link rate bounds (bits/sec); per-packet
      rates are Poisson with that mean (in Mbps), floored at 0.5 Mbps.
    churn: optional :class:`ChurnConfig`; None reproduces the paper's static
      setup exactly.
    """

    N: int = 100
    scenario: int = 1
    mu_choices: Tuple[float, ...] = (1.0, 2.0, 4.0)
    a_mode: str = "const"
    a_const: float = 0.5
    rate_lo: float = 10e6
    rate_hi: float = 20e6
    overhead: float = 0.05  # K = ceil(overhead * R)
    alpha: float = 0.25     # EWMA weight, eq. (4)
    churn: Optional[ChurnConfig] = None

    def K(self, R: int) -> int:
        return int(np.ceil(self.overhead * R))

    def ccp_cfg(self, R: int) -> ccp_mod.CCPConfig:
        # Paper: Bx = 8R bits, Br = 8 bits, Back = 1 bit.
        return ccp_mod.CCPConfig(Bx=8.0 * R, Br=8.0, Back=1.0, alpha=self.alpha)


def draw_helpers(key, cfg: ScenarioConfig):
    """Draw per-helper (mu_n, a_n, mean link rate)."""
    k1, k2 = jax.random.split(key)
    mu = jax.random.choice(k1, jnp.asarray(cfg.mu_choices), shape=(cfg.N,))
    if cfg.a_mode == "const":
        a = jnp.full((cfg.N,), cfg.a_const)
    elif cfg.a_mode == "inv_mu":
        a = 1.0 / mu
    else:
        raise ValueError(f"unknown a_mode {cfg.a_mode!r}")
    rate = jax.random.uniform(k2, (cfg.N,), minval=cfg.rate_lo, maxval=cfg.rate_hi)
    return mu, a, rate


def draw_packet_tables(key, cfg: ScenarioConfig, mu, a, rate, M: int, R: int):
    """Per-packet tables, each (N, M): beta, d_up, d_ack, d_down."""
    kb, ku, kd = jax.random.split(key, 3)
    N = cfg.N
    if cfg.scenario == 1:
        beta = a[:, None] + jax.random.exponential(kb, (N, M)) / mu[:, None]
    elif cfg.scenario == 2:
        b = a + jax.random.exponential(kb, (N,)) / mu
        beta = jnp.broadcast_to(b[:, None], (N, M))
    else:
        raise ValueError(f"scenario must be 1 or 2, got {cfg.scenario}")
    # Per-packet link rates: Poisson around the per-helper mean (in Mbps),
    # floored to avoid div-by-zero on a zero draw.
    lam = jnp.broadcast_to((rate / 1e6)[:, None], (N, M))
    up = jnp.maximum(jax.random.poisson(ku, lam, (N, M)).astype(jnp.float32), 0.5) * 1e6
    dn = jnp.maximum(jax.random.poisson(kd, lam, (N, M)).astype(jnp.float32), 0.5) * 1e6
    c = cfg.ccp_cfg(R)
    d_up = c.Bx / up
    d_ack = c.Back / dn
    d_down = c.Br / dn
    return beta, d_up, d_ack, d_down


def draw_dynamics(key, cfg: ScenarioConfig, M: int):
    """Churn tables: drop (N, M) per-packet loss, up/speed (N, P) per-phase.

    ``speed`` is the multiplicative service-rate factor (1 normal,
    1/slowdown degraded); ``up`` False means the helper is unreachable."""
    ch = cfg.churn
    kd, ku, ks = jax.random.split(key, 3)
    N, P = cfg.N, ch.n_phases
    drop = jax.random.bernoulli(kd, ch.drop_prob, (N, M))
    up = ~jax.random.bernoulli(ku, ch.p_down, (N, P))
    slow = jax.random.bernoulli(ks, ch.p_slow, (N, P))
    speed = jnp.where(slow, 1.0 / ch.slowdown, 1.0)
    return dict(drop=drop, up=up, speed=speed)


# ---------------------------------------------------------------------------
# The per-helper timeline scan
# ---------------------------------------------------------------------------

def _phase_lookup(table, t, period: float):
    """table (N, P) indexed by the wrapping phase of times t (N,)."""
    P = table.shape[1]
    ph = (jnp.floor_divide(t, period).astype(jnp.int32) % P)[:, None]
    return jnp.take_along_axis(table, ph, axis=1)[:, 0]


@functools.partial(
    jax.jit, static_argnames=("mode", "cfg_static", "churn_static")
)
def simulate_stream(beta, d_up, d_ack, d_down, mode: str, cfg_static,
                    churn_static=None, dyn=None, a=None, naive_to=None):
    """Simulate M packets on every helper. Returns dict of (N, M) arrays
    (plus ``tx_end`` (N,): the send time of the first unsimulated packet).

    mode: 'ccp'   — Algorithm 1 (estimated TTI, ring-buffer feedback delay,
                    and — under churn — the l.13-14 timeout/backoff path)
          'best'  — oracle TTI_{n,i} = beta_{n,i} (paper's Best, eq. 13)
          'naive' — stop-and-wait: tx_{i+1} = Tr_i (paper's Naive, eq. 16)
    cfg_static: hashable (Bx, Br, Back, alpha) tuple.
    churn_static: hashable (period, max_backoff) or None for the static
        paper model.  When set, ``dyn`` (from :func:`draw_dynamics`), ``a``
        (N,) runtime offsets, and — for 'naive' — ``naive_to`` (N,) fixed
        retransmission timeouts must be provided.
    """
    Bx, Br, Back, alpha = cfg_static
    cfg = ccp_mod.CCPConfig(Bx=Bx, Br=Br, Back=Back, alpha=alpha)
    N, M = beta.shape
    state0 = ccp_mod.init_state(N)
    churn = churn_static is not None
    if churn:
        period, max_backoff = churn_static

    carry0 = dict(
        tx=jnp.zeros(N),              # send time of current packet (Tx_{n,1}=0)
        done_prev=jnp.zeros(N),
        tr_prev=jnp.zeros(N),
        est=state0,
        ring_tr=jnp.full((N, RING), jnp.inf),
        ring_tti=jnp.zeros((N, RING)),
    )
    xs = dict(
        beta=beta.T, d_up=d_up.T, d_ack=d_ack.T, d_down=d_down.T,
        i=jnp.arange(M),
    )
    if churn:
        xs["drop"] = dyn["drop"].T

    def step(carry, x):
        tx = carry["tx"]
        arrive = tx + x["d_up"]
        start = jnp.maximum(arrive, carry["done_prev"])
        if churn:
            # Outage if the helper is down when the packet arrives or when
            # it would start computing; degraded phases stretch the runtime
            # (beta = a + eps/mu, so (beta-a)/speed rescales the random part).
            is_up = (_phase_lookup(dyn["up"], arrive, period)
                     & _phase_lookup(dyn["up"], start, period))
            sp = _phase_lookup(dyn["speed"], start, period)
            beta_i = jnp.where(sp == 1.0, x["beta"], a + (x["beta"] - a) / sp)
            lost = x["drop"] | ~is_up
        else:
            beta_i = x["beta"]
            lost = jnp.zeros((N,), bool)
        received = ~lost
        done_ok = start + beta_i
        tr_ok = done_ok + x["d_down"]
        # A lost packet never occupies the helper nor reaches the collector.
        done = jnp.where(lost, carry["done_prev"], done_ok)
        tr = jnp.where(lost, jnp.inf, tr_ok)
        idle = jnp.where(
            lost, 0.0, jnp.maximum(arrive - carry["done_prev"], 0.0)
        )
        rtt_ack = x["d_up"] + x["d_ack"]

        if mode == "ccp":
            est, _tti_i = ccp_mod.on_computed(
                carry["est"], cfg, tx, tr_ok, carry["tr_prev"], rtt_ack,
                active=received,
            )
            slot = x["i"] % RING
            ring_tr = carry["ring_tr"].at[:, slot].set(
                jnp.where(received, tr_ok, jnp.inf)
            )
            ring_tti = carry["ring_tti"].at[:, slot].set(est.e_beta)
            # E[beta] estimate in effect when planning the next send: the
            # entry with the largest Tr among those with Tr <= tx (latest
            # information that had arrived by the current send instant).
            valid = ring_tr <= tx[:, None]
            masked = jnp.where(valid, ring_tr, -jnp.inf)
            sel = jnp.argmax(masked, axis=1)
            has = valid.any(axis=1)
            e_beta_sel = jnp.take_along_axis(ring_tti, sel[:, None], axis=1)[:, 0]
            # eq. (8), causal form: tx_{i+1} = min(Tr_i, tx_i + E[beta]),
            # scaled by the timeout backoff factor (1 when no timeouts).
            # Bootstrap: before any computed packet has returned by tx, the
            # collector has no estimate -> stop-and-wait on this packet.
            tti_est = e_beta_sel * est.tti_backoff
            tx_next = jnp.where(has, jnp.minimum(tr_ok, tx + tti_est), tr_ok)
            if churn:
                # Alg. 1 lines 13-14 for a lost packet: the loss is detected
                # when TO = 2*(TTI + RTT^data) elapses (``timeout_deadline``
                # with the *pre-doubling* TTI), the stream resumes then, and
                # the backoff doubles (capped) for the following sends.
                # Consecutive losses therefore space out geometrically and a
                # receipt (on_computed above) resets the backoff — so a
                # helper that rejoins is re-ramped.  ``rtt_eff`` floors the
                # RTT term with this packet's scaled ACK sample so helpers
                # that never responded yet still have a finite deadline.
                rtt_eff = jnp.maximum(est.rtt_data, cfg.data_scale * rtt_ack)
                tti_pre = jnp.where(has, e_beta_sel, rtt_eff) * est.tti_backoff
                deadline = ccp_mod.timeout_deadline(
                    est.replace(rtt_data=rtt_eff), tti_pre
                )
                est = ccp_mod.on_timeout(est, lost, max_backoff=max_backoff)
                tx_next = jnp.where(lost, tx + deadline, tx_next)
        elif mode == "best":
            est = carry["est"]
            ring_tr, ring_tti = carry["ring_tr"], carry["ring_tti"]
            tx_next = tx + beta_i  # oracle: TTI_{n,i} = beta_{n,i}
        elif mode == "naive":
            est = carry["est"]
            ring_tr, ring_tti = carry["ring_tr"], carry["ring_tti"]
            tx_next = tr_ok
            if churn:
                # Stop-and-wait ARQ with a fixed (true-mean-based, i.e.
                # generous) retransmission timeout.
                tx_next = jnp.where(lost, tx + naive_to, tr_ok)
        else:
            raise ValueError(mode)

        new_carry = dict(
            tx=tx_next, done_prev=done,
            tr_prev=jnp.where(received, tr_ok, carry["tr_prev"]),
            est=est, ring_tr=ring_tr, ring_tti=ring_tti,
        )
        out = dict(tr=tr, idle=idle, tx=tx, arrive=arrive, beta=beta_i,
                   lost=lost, backoff=est.tti_backoff)
        return new_carry, out

    final, outs = jax.lax.scan(step, carry0, xs)
    res = {k: v.T for k, v in outs.items()}  # (N, M)
    res["tx_end"] = final["tx"]
    return res


# ---------------------------------------------------------------------------
# Completion-time + efficiency extraction
# ---------------------------------------------------------------------------

def completion_time(tr: jnp.ndarray, k: int,
                    tx_end: Optional[jnp.ndarray] = None
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Time when the k-th computed packet reaches the collector.

    Returns (T, valid): ``valid`` is False if the per-helper horizon M was too
    short to certify T (some helper might have contributed more packets by T
    than were simulated) — caller should re-run with a larger M.  With
    ``tx_end`` (the send time of the first unsimulated packet, which under
    churn can be finite even when the last simulated Tr is inf) certification
    uses "no helper would even have *sent* packet M+1 by T".
    """
    flat = jnp.sort(tr.reshape(-1))
    t = flat[k - 1]
    if tx_end is not None:
        valid = jnp.isfinite(t) & (t <= jnp.min(tx_end))
    else:
        valid = t <= jnp.min(tr[:, -1])
    return t, valid


def efficiency_measured(tr, idle, beta, t_end) -> jnp.ndarray:
    """Paper §6 'Efficiency': 1 - sum(idle)/sum(beta) over packets the helper
    computed within the completion horizon. Returns (N,) per-helper values."""
    within = tr <= t_end
    idle_sum = (idle * within).sum(axis=1)
    busy_sum = (beta * within).sum(axis=1)
    return jnp.where(busy_sum > 0, 1.0 - idle_sum / (idle_sum + busy_sum), jnp.nan)


# ---------------------------------------------------------------------------
# One Monte-Carlo rep (pure-jax core shared by the sequential and batched
# runners)
# ---------------------------------------------------------------------------

def _sim_one(key, cfg: ScenarioConfig, R: int, M: int, mode: str):
    """Full single-rep pipeline as a traceable function of ``key``."""
    k_h, k_p = jax.random.split(key)
    mu, a, rate = draw_helpers(k_h, cfg)
    beta, d_up, d_ack, d_down = draw_packet_tables(k_p, cfg, mu, a, rate, M, R)
    c = cfg.ccp_cfg(R)
    cfg_static = (c.Bx, c.Br, c.Back, c.alpha)
    if cfg.churn is None:
        outs = simulate_stream(beta, d_up, d_ack, d_down, mode=mode,
                               cfg_static=cfg_static)
        tx_end = None
    else:
        k_c = jax.random.fold_in(key, 0xC0DE)
        dyn = draw_dynamics(k_c, cfg, M)
        # Naive has no estimator (eq. 16 stop-and-wait), so its ARQ timer is
        # a *static* one provisioned for the slowest helper class — it cannot
        # adapt to per-helper speed, which is exactly what it pays for under
        # churn.
        mu_min = min(cfg.mu_choices)
        a_max = (cfg.a_const if cfg.a_mode == "const" else 1.0 / mu_min)
        naive_to = 2.0 * ((a_max + 1.0 / mu_min) + (c.Bx + c.Br) / rate)
        outs = simulate_stream(
            beta, d_up, d_ack, d_down, mode=mode, cfg_static=cfg_static,
            churn_static=(cfg.churn.period, cfg.churn.max_backoff),
            dyn=dyn, a=a, naive_to=naive_to,
        )
        tx_end = outs["tx_end"]
    kk = R + cfg.K(R)
    t, valid = completion_time(outs["tr"], kk, tx_end=tx_end)
    eff = efficiency_measured(outs["tr"], outs["idle"], outs["beta"], t)
    r_n = (outs["tr"] <= t).sum(axis=1)
    max_backoff = outs["backoff"].max(axis=1)
    lost_frac = outs["lost"].mean(axis=1)
    return dict(T=t, valid=valid, efficiency=eff, r_n=r_n, mu=mu, a=a,
                rate=rate, max_backoff=max_backoff, lost_frac=lost_frac)


@functools.partial(jax.jit, static_argnames=("cfg", "R", "M", "mode"))
def _sim_one_jit(key, cfg, R, M, mode):
    return _sim_one(key, cfg, R, M, mode)


@functools.partial(jax.jit, static_argnames=("cfg", "R", "M", "mode"))
def _sim_batch_jit(keys, cfg, R, M, mode):
    return jax.vmap(lambda k: _sim_one(k, cfg, R, M, mode))(keys)


def _m_cap(cfg: ScenarioConfig, kk: int) -> int:
    # Static: every helper streams back-to-back, so M = R+K always certifies.
    # Under churn a helper's M packets can include losses — leave headroom.
    return kk if cfg.churn is None else 4 * kk


def _bucketed_horizon(cfg: ScenarioConfig, share: float, k: int) -> int:
    """~3x the fastest helper's fair share, bucketed to a power of two to
    limit jit recompiles across the R sweep, capped at _m_cap."""
    m = int(np.ceil(3.0 * k * share)) + 64
    bucket = 1 << int(np.ceil(np.log2(max(m, 64))))
    return min(bucket, _m_cap(cfg, k))


def _horizon(cfg: ScenarioConfig, mu, a, R: int) -> int:
    """Per-draw horizon for the sequential runner."""
    k = R + cfg.K(R)
    w = 1.0 / theory.shifted_exp_mean(np.asarray(a), np.asarray(mu))
    return _bucketed_horizon(cfg, float(w.max() / w.sum()), k)


def _horizon_shared(cfg: ScenarioConfig, R: int) -> int:
    """Key-independent horizon for the batched runner: the expected fastest
    helper's share from the mu/a choice set (certification re-runs with a
    doubled horizon when a draw lands above it)."""
    k = R + cfg.K(R)
    mu = np.asarray(cfg.mu_choices, dtype=np.float64)
    a = 1.0 / mu if cfg.a_mode == "inv_mu" else np.full_like(mu, cfg.a_const)
    w = 1.0 / theory.shifted_exp_mean(a, mu)
    return _bucketed_horizon(cfg, float(w.max() / (cfg.N * w.mean())), k)


# ---------------------------------------------------------------------------
# Top-level runners
# ---------------------------------------------------------------------------

def _run_mode(key, cfg: ScenarioConfig, R: int, mode: str,
              M_override: Optional[int] = None) -> Dict[str, np.ndarray]:
    k_h, _ = jax.random.split(key)
    mu, a, _rate = draw_helpers(k_h, cfg)
    kk = R + cfg.K(R)
    cap = _m_cap(cfg, kk)
    M = M_override if M_override is not None else _horizon(cfg, mu, a, R)
    for _ in range(8):  # grow horizon until the order statistic is certified
        out = _sim_one_jit(key, cfg, R, M, mode)
        if bool(out["valid"]) or M >= cap or M_override is not None:
            break
        M = min(M * 2, cap)
    res = {k: np.asarray(v) for k, v in out.items()}
    res["T"] = float(res["T"])
    res["M"] = M
    return res


def run_ccp(key, cfg: ScenarioConfig, R: int):
    return _run_mode(key, cfg, R, "ccp")


def run_best(key, cfg: ScenarioConfig, R: int):
    return _run_mode(key, cfg, R, "best")


def run_naive(key, cfg: ScenarioConfig, R: int):
    return _run_mode(key, cfg, R, "naive")


def batch_keys(reps: int, seed0: int = 0) -> jnp.ndarray:
    """The batched counterpart of ``PRNGKey(seed0 * 100003 + r)`` per rep."""
    return jax.vmap(jax.random.PRNGKey)(seed0 * 100003 + jnp.arange(reps))


def run_batch(keys, cfg: ScenarioConfig, R: int, mode: str,
              M_override: Optional[int] = None) -> Dict[str, np.ndarray]:
    """Vmapped Monte-Carlo over a batch of PRNG keys (see module docstring).

    Returns a dict of stacked arrays: T (B,), valid (B,), efficiency (B, N),
    r_n, mu, a, rate, max_backoff, lost_frac (B, N), plus the shared horizon
    M actually used.  All reps share one bucketed horizon; if any rep's
    completion time is uncertified the horizon doubles and the batch re-runs.
    """
    keys = jnp.asarray(keys)
    kk = R + cfg.K(R)
    cap = _m_cap(cfg, kk)
    M = M_override if M_override is not None else _horizon_shared(cfg, R)
    for _ in range(8):
        out = _sim_batch_jit(keys, cfg, R, M, mode)
        if bool(out["valid"].all()) or M >= cap or M_override is not None:
            break
        M = min(M * 2, cap)
    res = {k: np.asarray(v) for k, v in out.items()}
    res["M"] = M
    return res
