"""Vectorized discrete-event simulation of CCP and the paper's baselines.

Reproduces §6 of the paper: a collector offloads fountain-coded packets to
``N`` heterogeneous helpers over links with random per-packet rates; helper
``n`` computes packet ``i`` in ``beta_{n,i}`` (Scenario 1: i.i.d.
shifted-exponential per packet; Scenario 2: one draw per helper).  The
completion time is when the collector has received ``R+K`` computed packets.

Instead of a global event queue (O(N*R) sequential events), we exploit that
helpers only couple through the *stopping rule*: each helper's packet
timeline is an independent recurrence, so we

  1. scan each helper's timeline for ``M`` packets (vectorized over helpers,
     ``lax.scan`` over the packet index),
  2. merge the computed-packet arrival times ``Tr`` across helpers and take
     the (R+K)-th order statistic as the completion time.

The CCP send rule, eq. (8) ``TTI_i = min(Tr_i - Tx_i, E[beta])``, is *causal*
when read operationally:  ``tx_{i+1} = min(Tr_i, tx_i + E[beta])`` — send the
next packet either the moment the previous computed result returns (the
helper finished early) or when ``E[beta]`` has elapsed since the last send
(the cap), whichever happens first.  The ``E[beta]`` estimate in effect is
the latest one whose computed packet had returned by ``tx_i`` (held in a
small ring buffer).  Until the first computed packet returns the collector
has no estimate and falls back to stop-and-wait — this reproduces the
startup under-utilization the paper reports in §6 (Efficiency).

Timing model per packet (helper n, packet i):
  arrive_i = tx_i + d_up_i                      (uplink)
  start_i  = max(arrive_i, done_{i-1})          (FIFO helper queue)
  done_i   = start_i + beta_i
  Tr_i     = done_i + d_down_i                  (result downlink)
  RTTack_i = d_up_i + d_ack_i                   (receipt ACK, measured)
  idle_i   = max(0, arrive_i - done_{i-1})      (helper under-utilization)

Dynamics / churn (beyond the paper's static Scenarios 1-2)
----------------------------------------------------------
``ScenarioConfig.churn = ChurnConfig(...)`` switches on a time-varying
resource model built from three loss processes plus a slowdown process.
Time is divided into phases of ``period`` seconds (``n_phases`` distinct
phases, wrapping around after ``n_phases * period`` seconds).

1. **Per-helper outages.**  With the default ``outage_dist='phase'`` a
   helper is independently *down* for whole phases with per-phase prob
   ``p_down`` (the PR-1 Bernoulli model).  With ``outage_dist='geometric'``
   or ``'lognormal'`` an outage *starts* at a phase boundary with prob
   ``p_down`` but lasts a sampled duration — geometric over whole periods
   (mean ``outage_mean``) or log-normal (mean ``outage_mean``, log-std
   ``outage_sigma``) — so downtime is bursty in time rather than
   memoryless per phase.  Packets that arrive (or would start computing)
   while the helper is down are lost.

2. **Gilbert–Elliott burst loss** (per helper, per packet).  A two-state
   Markov chain over packet indices: good -> bad with prob ``ge_p_bad``,
   bad -> good with prob ``ge_p_good``; a packet sent in the good state is
   lost with prob ``ge_loss_good``, in the bad state with ``ge_loss_bad``.
   The chain starts in its stationary distribution, so the marginal loss
   rate is ``pi_bad*ge_loss_bad + (1-pi_bad)*ge_loss_good`` with
   ``pi_bad = ge_p_bad / (ge_p_bad + ge_p_good)``.  This models bursty
   radio-link fades that i.i.d. ``drop_prob`` cannot express
   (cf. arXiv:2103.04247's correlated-erasure setting).

3. **Correlated whole-cell outages.**  With per-phase prob ``p_cell`` an
   outage *event* starts uniformly inside the phase; each helper belongs to
   the affected cell independently with prob ``cell_frac`` and every member
   is down simultaneously for the event's sampled duration (same duration
   distribution as (1); ``outage_dist='phase'`` means one full period).
   This takes correlated subsets of helpers down at once — the failure
   mode a per-helper model cannot produce.

On top, each packet is lost i.i.d. with prob ``drop_prob``, and a helper is
*degraded* per phase with prob ``p_slow`` (its service rate ``mu_n`` is
divided by ``slowdown``).  A lost packet never produces a ``Tr``; the
collector reacts with Algorithm 1 lines 13-14: the TTI backoff doubles
(``ccp.on_timeout``, capped at ``max_backoff``) and the retransmission
fires at the timeout deadline ``TO = 2*(TTI + RTT^data)``
(``ccp.timeout_deadline`` form).  A successful receipt resets the backoff,
so helpers that rejoin are re-ramped.  ``churn=None`` (default) runs the
exact static paper model, and a ``ChurnConfig`` with every loss knob at
zero is bit-for-bit identical to it.

Batched Monte-Carlo (``run_batch``)
-----------------------------------
``run_batch(keys, cfg, R, mode)`` vmaps the whole per-rep pipeline (helper
draw -> packet tables -> stream scan -> order statistic) over a batch of
PRNG keys with one shared, power-of-two-bucketed horizon ``M`` and a single
certification pass: if any rep's order statistic is uncertified the shared
horizon doubles and the whole batch re-runs (one extra compile, amortized
across the sweep).  Typical usage::

    keys = simulator.batch_keys(reps=40, seed0=0)
    out = simulator.run_batch(keys, cfg, R=2000, mode="ccp")
    out["T"]           # (reps,) completion times
    out["efficiency"]  # (reps, N) per-helper measured efficiency

This replaces a Python loop of ``reps`` jitted calls with one vmapped call
and is the engine behind ``benchmarks/fig3|4|5|churn``.  With
``shard=True`` the key batch is additionally split across the local
devices through ``shard_map`` on a 1-D 'data' mesh (padded to a
device-count multiple); per-rep lanes never communicate, so the sharded
results are identical to the unsharded vmap.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import ccp as ccp_mod
from . import theory

__all__ = [
    "ChurnConfig",
    "ScenarioConfig",
    "draw_helpers",
    "draw_packet_tables",
    "draw_dynamics",
    "simulate_stream",
    "completion_time",
    "batch_keys",
    "run_batch",
    "run_ccp",
    "run_best",
    "run_naive",
    "run_naive_oracle",
    "KEY_SCHEDULE",
    "RING",
]

RING = 16  # ring-buffer slots for in-flight (Tr, TTI) pairs


# ---------------------------------------------------------------------------
# Configuration and random draws
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ChurnConfig:
    """Time-varying resource model (see module docstring for the three loss
    processes).

    period:     phase length in seconds; helper states re-randomize each
                phase, so ``period`` sets the churn timescale.
    n_phases:   distinct phases drawn; the schedule wraps (mod) beyond that.
    p_down:     per-phase prob a helper outage (packets sent to it are lost).
    p_slow:     per-phase prob a helper is degraded (mu_n / slowdown).
    slowdown:   service-rate divisor while degraded.
    drop_prob:  i.i.d. per-packet loss on top of outages.
    max_backoff: cap on the Alg.-1 line-13 multiplicative TTI backoff so a
                rejoining helper is re-probed within a bounded interval.
    outage_dist: outage-duration law for helper and cell outages — 'phase'
                (whole phases, the PR-1 Bernoulli model), 'geometric'
                (whole periods, mean ``outage_mean``) or 'lognormal'
                (continuous, mean ``outage_mean``, log-std ``outage_sigma``).
    outage_mean: mean outage duration in seconds for the duration laws.
    outage_sigma: log-std of the log-normal duration law.
    ge_p_bad:   Gilbert–Elliott good->bad transition prob per packet
                (0 disables the GE chain entirely).
    ge_p_good:  GE bad->good transition prob per packet.
    ge_loss_good / ge_loss_bad: per-packet loss prob in each GE state.
    p_cell:     per-phase prob a correlated whole-cell outage event starts.
    cell_frac:  prob each helper belongs to a given cell event.
    """

    period: float = 5.0
    n_phases: int = 16
    p_down: float = 0.0
    p_slow: float = 0.0
    slowdown: float = 4.0
    drop_prob: float = 0.0
    max_backoff: float = 8.0
    outage_dist: str = "phase"
    outage_mean: float = 5.0
    outage_sigma: float = 0.5
    ge_p_bad: float = 0.0
    ge_p_good: float = 0.25
    ge_loss_good: float = 0.0
    ge_loss_bad: float = 1.0
    p_cell: float = 0.0
    cell_frac: float = 0.5

    def __post_init__(self):
        if self.outage_dist not in ("phase", "geometric", "lognormal"):
            raise ValueError(
                f"outage_dist must be 'phase', 'geometric' or 'lognormal', "
                f"got {self.outage_dist!r}"
            )

    @property
    def ge_enabled(self) -> bool:
        return self.ge_p_bad > 0.0

    @property
    def cell_enabled(self) -> bool:
        return self.p_cell > 0.0

    @property
    def ge_stationary_bad(self) -> float:
        """Stationary P(bad) of the GE chain (0 when disabled)."""
        denom = self.ge_p_bad + self.ge_p_good
        return self.ge_p_bad / denom if denom > 0 else 0.0

    @property
    def ge_loss_rate(self) -> float:
        """Stationary marginal per-packet GE loss rate."""
        pb = self.ge_stationary_bad
        return pb * self.ge_loss_bad + (1.0 - pb) * self.ge_loss_good

    @property
    def neutral(self) -> bool:
        return (self.p_down == 0.0 and self.p_slow == 0.0
                and self.drop_prob == 0.0 and not self.ge_enabled
                and not self.cell_enabled)

    def static_key(self) -> tuple:
        """Hashable tuple of the *structural* knobs ``simulate_stream``
        specializes on (passed as its static ``churn_static`` argument)."""
        return (self.period, self.max_backoff, self.outage_dist,
                self.ge_enabled, self.cell_enabled)


@dataclasses.dataclass(frozen=True)
class ScenarioConfig:
    """Paper §6 simulation setup.

    scenario: 1 (i.i.d. per-packet runtimes / Model I) or
              2 (one runtime draw per helper / Model II).
    a_mode:   'const' -> a_n = a_const;  'inv_mu' -> a_n = 1/mu_n.
    mu_choices: helper speeds drawn uniformly from this set.
    rate_lo/rate_hi: per-helper mean link rate bounds (bits/sec); per-packet
      rates are Poisson with that mean (in Mbps), floored at 0.5 Mbps.
    churn: optional :class:`ChurnConfig`; None reproduces the paper's static
      setup exactly.
    """

    N: int = 100
    scenario: int = 1
    mu_choices: Tuple[float, ...] = (1.0, 2.0, 4.0)
    a_mode: str = "const"
    a_const: float = 0.5
    rate_lo: float = 10e6
    rate_hi: float = 20e6
    overhead: float = 0.05  # K = ceil(overhead * R)
    alpha: float = 0.25     # EWMA weight, eq. (4)
    churn: Optional[ChurnConfig] = None

    def K(self, R: int) -> int:
        return int(np.ceil(self.overhead * R))

    def ccp_cfg(self, R: int) -> ccp_mod.CCPConfig:
        # Paper: Bx = 8R bits, Br = 8 bits, Back = 1 bit.
        return ccp_mod.CCPConfig(Bx=8.0 * R, Br=8.0, Back=1.0, alpha=self.alpha)


def draw_helpers(key, cfg: ScenarioConfig):
    """Draw per-helper (mu_n, a_n, mean link rate)."""
    k1, k2 = jax.random.split(key)
    mu = jax.random.choice(k1, jnp.asarray(cfg.mu_choices), shape=(cfg.N,))
    if cfg.a_mode == "const":
        a = jnp.full((cfg.N,), cfg.a_const)
    elif cfg.a_mode == "inv_mu":
        a = 1.0 / mu
    else:
        raise ValueError(f"unknown a_mode {cfg.a_mode!r}")
    rate = jax.random.uniform(k2, (cfg.N,), minval=cfg.rate_lo, maxval=cfg.rate_hi)
    return mu, a, rate


def draw_packet_tables(key, cfg: ScenarioConfig, mu, a, rate, M: int, R: int):
    """Per-packet tables, each (N, M): beta, d_up, d_ack, d_down."""
    kb, ku, kd = jax.random.split(key, 3)
    N = cfg.N
    if cfg.scenario == 1:
        beta = a[:, None] + jax.random.exponential(kb, (N, M)) / mu[:, None]
    elif cfg.scenario == 2:
        b = a + jax.random.exponential(kb, (N,)) / mu
        beta = jnp.broadcast_to(b[:, None], (N, M))
    else:
        raise ValueError(f"scenario must be 1 or 2, got {cfg.scenario}")
    # Per-packet link rates: Poisson around the per-helper mean (in Mbps),
    # floored to avoid div-by-zero on a zero draw.
    lam = jnp.broadcast_to((rate / 1e6)[:, None], (N, M))
    up = jnp.maximum(jax.random.poisson(ku, lam, (N, M)).astype(jnp.float32), 0.5) * 1e6
    dn = jnp.maximum(jax.random.poisson(kd, lam, (N, M)).astype(jnp.float32), 0.5) * 1e6
    c = cfg.ccp_cfg(R)
    d_up = c.Bx / up
    d_ack = c.Back / dn
    d_down = c.Br / dn
    return beta, d_up, d_ack, d_down


def _draw_durations(key, ch: ChurnConfig, shape):
    """Outage durations (seconds) under ``ch.outage_dist``.

    'phase' -> exactly one period (the PR-1 whole-phase outage);
    'geometric' -> whole periods, Geometric(period/outage_mean), mean
    ``max(outage_mean, period)``; 'lognormal' -> continuous, mean
    ``outage_mean``, log-std ``outage_sigma``."""
    if ch.outage_dist == "geometric":
        p = min(1.0, ch.period / max(ch.outage_mean, ch.period))
        k = jax.random.geometric(key, p, shape)
        return k.astype(jnp.float32) * ch.period
    if ch.outage_dist == "lognormal":
        mu_log = np.log(ch.outage_mean) - 0.5 * ch.outage_sigma ** 2
        z = jax.random.normal(key, shape)
        return jnp.exp(mu_log + ch.outage_sigma * z)
    return jnp.full(shape, ch.period)


def draw_dynamics(key, cfg: ScenarioConfig, M: int):
    """Churn tables for one rep (see module docstring for the processes).

    Always: ``drop`` (N, M) i.i.d. per-packet loss and ``speed`` (N, P)
    per-phase service-rate factor (1 normal, 1/slowdown degraded).
    Per-helper outages: ``up`` (N, P) phase table when
    ``outage_dist='phase'``, else ``out_start``/``out_end`` (N, P) absolute
    intervals inside the wrapping window ``n_phases * period``.
    When enabled: ``cell_start``/``cell_end`` (P,) + ``cell_mask`` (N, P)
    correlated-outage events, and ``ge_bad0`` (N,) initial states +
    ``ge_u_trans``/``ge_u_loss`` (N, M) uniforms for the Gilbert–Elliott
    chain (its four probabilities ride along as traced scalars in
    ``ge_params`` so sweeping them does not retrace)."""
    ch = cfg.churn
    kd, ku, ks, kdur, kc, kg = jax.random.split(key, 6)
    N, P = cfg.N, ch.n_phases
    dyn = dict(
        drop=jax.random.bernoulli(kd, ch.drop_prob, (N, M)),
        speed=jnp.where(jax.random.bernoulli(ks, ch.p_slow, (N, P)),
                        1.0 / ch.slowdown, 1.0),
    )
    if ch.outage_dist == "phase":
        dyn["up"] = ~jax.random.bernoulli(ku, ch.p_down, (N, P))
    else:
        ev = jax.random.bernoulli(ku, ch.p_down, (N, P))
        start = jnp.broadcast_to(jnp.arange(P) * ch.period, (N, P))
        dur = _draw_durations(kdur, ch, (N, P))
        dyn["out_start"] = jnp.where(ev, start, jnp.inf)
        dyn["out_end"] = jnp.where(ev, start + dur, -jnp.inf)
    if ch.cell_enabled:
        ke, ko, kl, km = jax.random.split(kc, 4)
        ev = jax.random.bernoulli(ke, ch.p_cell, (P,))
        start = jnp.arange(P) * ch.period + \
            jax.random.uniform(ko, (P,)) * ch.period
        dur = _draw_durations(kl, ch, (P,))
        dyn["cell_start"] = jnp.where(ev, start, jnp.inf)
        dyn["cell_end"] = jnp.where(ev, start + dur, -jnp.inf)
        dyn["cell_mask"] = jax.random.bernoulli(km, ch.cell_frac, (N, P))
    if ch.ge_enabled:
        kb, kt, klo = jax.random.split(kg, 3)
        dyn["ge_bad0"] = jax.random.bernoulli(kb, ch.ge_stationary_bad, (N,))
        dyn["ge_u_trans"] = jax.random.uniform(kt, (N, M))
        dyn["ge_u_loss"] = jax.random.uniform(klo, (N, M))
        dyn["ge_params"] = jnp.asarray(
            [ch.ge_p_bad, ch.ge_p_good, ch.ge_loss_good, ch.ge_loss_bad]
        )
    return dyn


# ---------------------------------------------------------------------------
# The per-helper timeline scan
# ---------------------------------------------------------------------------

def _phase_lookup(table, t, period: float):
    """table (N, P) indexed by the wrapping phase of times t (N,)."""
    P = table.shape[1]
    ph = (jnp.floor_divide(t, period).astype(jnp.int32) % P)[:, None]
    return jnp.take_along_axis(table, ph, axis=1)[:, 0]


def _interval_hit(start, end, t, window: float):
    """Per-interval membership of times t (N,) in [start, end) intervals,
    with the schedule wrapping every ``window`` seconds.  Returns (N, P).

    start/end are (N, P) per-helper intervals or (P,) shared event times
    (broadcast against the N axis).  Intervals are laid out in absolute
    time inside [0, window); an interval whose end spills past the window
    also covers the wrapped tail [0, end - window)."""
    tm = jnp.mod(t, window)[:, None]
    if start.ndim == 1:
        start, end = start[None, :], end[None, :]
    return ((tm >= start) & (tm < end)) | (tm < (end - window))


@functools.partial(
    jax.jit, static_argnames=("mode", "cfg_static", "churn_static")
)
def simulate_stream(beta, d_up, d_ack, d_down, mode: str, cfg_static,
                    churn_static=None, dyn=None, a=None, naive_to=None):
    """Simulate M packets on every helper. Returns dict of (N, M) arrays
    (plus ``tx_end`` (N,): the send time of the first unsimulated packet).

    mode: 'ccp'   — Algorithm 1 (estimated TTI, ring-buffer feedback delay,
                    and — under churn — the l.13-14 timeout/backoff path)
          'best'  — oracle TTI_{n,i} = beta_{n,i} (paper's Best, eq. 13)
          'naive' — stop-and-wait: tx_{i+1} = Tr_i (paper's Naive, eq. 16)
    cfg_static: hashable (Bx, Br, Back, alpha) tuple.
    churn_static: ``ChurnConfig.static_key()`` — hashable (period,
        max_backoff, outage_dist, ge_enabled, cell_enabled) — or the legacy
        (period, max_backoff) 2-tuple (phase outages only), or None for the
        static paper model.  When set, ``dyn`` (from :func:`draw_dynamics`),
        ``a`` (N,) runtime offsets, and — for 'naive' — ``naive_to`` (N,)
        fixed retransmission timeouts must be provided.
    """
    Bx, Br, Back, alpha = cfg_static
    cfg = ccp_mod.CCPConfig(Bx=Bx, Br=Br, Back=Back, alpha=alpha)
    N, M = beta.shape
    state0 = ccp_mod.init_state(N)
    churn = churn_static is not None
    ge_on = cell_on = False
    outage_dist = "phase"
    if churn:
        if len(churn_static) == 2:  # legacy direct callers (phase model)
            period, max_backoff = churn_static
        else:
            period, max_backoff, outage_dist, ge_on, cell_on = churn_static
        window = period * dyn["speed"].shape[1]

    carry0 = dict(
        tx=jnp.zeros(N),              # send time of current packet (Tx_{n,1}=0)
        done_prev=jnp.zeros(N),
        tr_prev=jnp.zeros(N),
        est=state0,
        ring_tr=jnp.full((N, RING), jnp.inf),
        ring_tti=jnp.zeros((N, RING)),
    )
    xs = dict(
        beta=beta.T, d_up=d_up.T, d_ack=d_ack.T, d_down=d_down.T,
        i=jnp.arange(M),
    )
    if churn:
        xs["drop"] = dyn["drop"].T
    if ge_on:
        carry0["ge_bad"] = dyn["ge_bad0"]
        xs["ge_u_trans"] = dyn["ge_u_trans"].T
        xs["ge_u_loss"] = dyn["ge_u_loss"].T

    def step(carry, x):
        tx = carry["tx"]
        arrive = tx + x["d_up"]
        start = jnp.maximum(arrive, carry["done_prev"])
        if churn:
            # Outage if the helper is down when the packet arrives or when
            # it would start computing; degraded phases stretch the runtime
            # (beta = a + eps/mu, so (beta-a)/speed rescales the random part).
            if outage_dist == "phase":
                is_up = (_phase_lookup(dyn["up"], arrive, period)
                         & _phase_lookup(dyn["up"], start, period))
            else:
                is_up = ~(_interval_hit(dyn["out_start"], dyn["out_end"],
                                        arrive, window)
                          | _interval_hit(dyn["out_start"], dyn["out_end"],
                                          start, window)).any(axis=1)
            if cell_on:
                in_cell = dyn["cell_mask"] & (
                    _interval_hit(dyn["cell_start"], dyn["cell_end"],
                                  arrive, window)
                    | _interval_hit(dyn["cell_start"], dyn["cell_end"],
                                    start, window)
                )
                is_up &= ~in_cell.any(axis=1)
            sp = _phase_lookup(dyn["speed"], start, period)
            beta_i = jnp.where(sp == 1.0, x["beta"], a + (x["beta"] - a) / sp)
            lost = x["drop"] | ~is_up
        else:
            beta_i = x["beta"]
            lost = jnp.zeros((N,), bool)
        if ge_on:
            # Gilbert–Elliott: loss by the current state, then the per-packet
            # state transition (the chain advances even for packets already
            # lost to an outage — the radio fades regardless).
            p_bad, p_good, l_good, l_bad = dyn["ge_params"]
            bad = carry["ge_bad"]
            lost |= x["ge_u_loss"] < jnp.where(bad, l_bad, l_good)
            ge_bad_next = jnp.where(
                bad, x["ge_u_trans"] >= p_good, x["ge_u_trans"] < p_bad
            )
        received = ~lost
        done_ok = start + beta_i
        tr_ok = done_ok + x["d_down"]
        # A lost packet never occupies the helper nor reaches the collector.
        done = jnp.where(lost, carry["done_prev"], done_ok)
        tr = jnp.where(lost, jnp.inf, tr_ok)
        idle = jnp.where(
            lost, 0.0, jnp.maximum(arrive - carry["done_prev"], 0.0)
        )
        rtt_ack = x["d_up"] + x["d_ack"]

        if mode == "ccp":
            est, _tti_i = ccp_mod.on_computed(
                carry["est"], cfg, tx, tr_ok, carry["tr_prev"], rtt_ack,
                active=received,
            )
            slot = x["i"] % RING
            ring_tr = carry["ring_tr"].at[:, slot].set(
                jnp.where(received, tr_ok, jnp.inf)
            )
            ring_tti = carry["ring_tti"].at[:, slot].set(est.e_beta)
            # E[beta] estimate in effect when planning the next send: the
            # entry with the largest Tr among those with Tr <= tx (latest
            # information that had arrived by the current send instant).
            valid = ring_tr <= tx[:, None]
            masked = jnp.where(valid, ring_tr, -jnp.inf)
            sel = jnp.argmax(masked, axis=1)
            has = valid.any(axis=1)
            e_beta_sel = jnp.take_along_axis(ring_tti, sel[:, None], axis=1)[:, 0]
            # eq. (8), causal form: tx_{i+1} = min(Tr_i, tx_i + E[beta]),
            # scaled by the timeout backoff factor (1 when no timeouts).
            # Bootstrap: before any computed packet has returned by tx, the
            # collector has no estimate -> stop-and-wait on this packet.
            tti_est = e_beta_sel * est.tti_backoff
            tx_next = jnp.where(has, jnp.minimum(tr_ok, tx + tti_est), tr_ok)
            if churn:
                # Alg. 1 lines 13-14 for a lost packet: the loss is detected
                # when TO = 2*(TTI + RTT^data) elapses (``timeout_deadline``
                # with the *pre-doubling* TTI), the stream resumes then, and
                # the backoff doubles (capped) for the following sends.
                # Consecutive losses therefore space out geometrically and a
                # receipt (on_computed above) resets the backoff — so a
                # helper that rejoins is re-ramped.  ``rtt_eff`` floors the
                # RTT term with this packet's scaled ACK sample so helpers
                # that never responded yet still have a finite deadline.
                rtt_eff = jnp.maximum(est.rtt_data, cfg.data_scale * rtt_ack)
                tti_pre = jnp.where(has, e_beta_sel, rtt_eff) * est.tti_backoff
                deadline = ccp_mod.timeout_deadline(
                    est.replace(rtt_data=rtt_eff), tti_pre
                )
                est = ccp_mod.on_timeout(est, lost, max_backoff=max_backoff)
                tx_next = jnp.where(lost, tx + deadline, tx_next)
        elif mode == "best":
            est = carry["est"]
            ring_tr, ring_tti = carry["ring_tr"], carry["ring_tti"]
            tx_next = tx + beta_i  # oracle: TTI_{n,i} = beta_{n,i}
        elif mode == "naive":
            est = carry["est"]
            ring_tr, ring_tti = carry["ring_tr"], carry["ring_tti"]
            tx_next = tr_ok
            if churn:
                # Stop-and-wait ARQ with a fixed (true-mean-based, i.e.
                # generous) retransmission timeout.
                tx_next = jnp.where(lost, tx + naive_to, tr_ok)
        else:
            raise ValueError(mode)

        new_carry = dict(
            tx=tx_next, done_prev=done,
            tr_prev=jnp.where(received, tr_ok, carry["tr_prev"]),
            est=est, ring_tr=ring_tr, ring_tti=ring_tti,
        )
        if ge_on:
            new_carry["ge_bad"] = ge_bad_next
        out = dict(tr=tr, idle=idle, tx=tx, arrive=arrive, beta=beta_i,
                   lost=lost, backoff=est.tti_backoff)
        return new_carry, out

    final, outs = jax.lax.scan(step, carry0, xs)
    res = {k: v.T for k, v in outs.items()}  # (N, M)
    res["tx_end"] = final["tx"]
    return res


# ---------------------------------------------------------------------------
# Completion-time + efficiency extraction
# ---------------------------------------------------------------------------

def completion_time(tr: jnp.ndarray, k: int,
                    tx_end: Optional[jnp.ndarray] = None
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Time when the k-th computed packet reaches the collector.

    Returns (T, valid): ``valid`` is False if the per-helper horizon M was too
    short to certify T (some helper might have contributed more packets by T
    than were simulated) — caller should re-run with a larger M.  With
    ``tx_end`` (the send time of the first unsimulated packet, which under
    churn can be finite even when the last simulated Tr is inf) certification
    uses "no helper would even have *sent* packet M+1 by T".
    """
    flat = jnp.sort(tr.reshape(-1))
    t = flat[k - 1]
    if tx_end is not None:
        valid = jnp.isfinite(t) & (t <= jnp.min(tx_end))
    else:
        valid = t <= jnp.min(tr[:, -1])
    return t, valid


def efficiency_measured(tr, idle, beta, t_end) -> jnp.ndarray:
    """Paper §6 'Efficiency': 1 - sum(idle)/sum(beta) over packets the helper
    computed within the completion horizon. Returns (N,) per-helper values."""
    within = tr <= t_end
    idle_sum = (idle * within).sum(axis=1)
    busy_sum = (beta * within).sum(axis=1)
    return jnp.where(busy_sum > 0, 1.0 - idle_sum / (idle_sum + busy_sum), jnp.nan)


# ---------------------------------------------------------------------------
# One Monte-Carlo rep (pure-jax core shared by the sequential and batched
# runners)
# ---------------------------------------------------------------------------

def _sim_one(key, cfg: ScenarioConfig, R: int, M: int, mode: str):
    """Full single-rep pipeline as a traceable function of ``key``.

    ``mode`` adds 'naive_oracle' on top of simulate_stream's modes: the
    same stop-and-wait stream as 'naive' but with a per-helper *oracle*
    ARQ timer built from the true (unobservable) mean runtime and link
    rate — it separates Naive's pipelining loss from its timer-adaptation
    loss in the churn benchmarks (ROADMAP follow-up)."""
    k_h, k_p = jax.random.split(key)
    mu, a, rate = draw_helpers(k_h, cfg)
    beta, d_up, d_ack, d_down = draw_packet_tables(k_p, cfg, mu, a, rate, M, R)
    c = cfg.ccp_cfg(R)
    cfg_static = (c.Bx, c.Br, c.Back, c.alpha)
    stream_mode = "naive" if mode == "naive_oracle" else mode
    if cfg.churn is None:
        outs = simulate_stream(beta, d_up, d_ack, d_down, mode=stream_mode,
                               cfg_static=cfg_static)
        tx_end = None
    else:
        k_c = jax.random.fold_in(key, 0xC0DE)
        dyn = draw_dynamics(k_c, cfg, M)
        if mode == "naive_oracle":
            # Oracle timer: the true per-helper mean runtime + data RTT.
            naive_to = ccp_mod.arq_timeout(a + 1.0 / mu, (c.Bx + c.Br) / rate)
        else:
            # Naive has no estimator (eq. 16 stop-and-wait), so its ARQ
            # timer is a *static* one provisioned for the slowest helper
            # class — it cannot adapt to per-helper speed, which is exactly
            # what it pays for under churn.
            mu_min = min(cfg.mu_choices)
            a_max = (cfg.a_const if cfg.a_mode == "const" else 1.0 / mu_min)
            naive_to = ccp_mod.arq_timeout(
                a_max + 1.0 / mu_min, (c.Bx + c.Br) / rate
            )
        outs = simulate_stream(
            beta, d_up, d_ack, d_down, mode=stream_mode,
            cfg_static=cfg_static, churn_static=cfg.churn.static_key(),
            dyn=dyn, a=a, naive_to=naive_to,
        )
        tx_end = outs["tx_end"]
    kk = R + cfg.K(R)
    t, valid = completion_time(outs["tr"], kk, tx_end=tx_end)
    eff = efficiency_measured(outs["tr"], outs["idle"], outs["beta"], t)
    r_n = (outs["tr"] <= t).sum(axis=1)
    max_backoff = outs["backoff"].max(axis=1)
    lost_frac = outs["lost"].mean(axis=1)
    return dict(T=t, valid=valid, efficiency=eff, r_n=r_n, mu=mu, a=a,
                rate=rate, max_backoff=max_backoff, lost_frac=lost_frac)


@functools.partial(jax.jit, static_argnames=("cfg", "R", "M", "mode"))
def _sim_one_jit(key, cfg, R, M, mode):
    return _sim_one(key, cfg, R, M, mode)


@functools.partial(jax.jit, static_argnames=("cfg", "R", "M", "mode"))
def _sim_batch_jit(keys, cfg, R, M, mode):
    return jax.vmap(lambda k: _sim_one(k, cfg, R, M, mode))(keys)


def _m_cap(cfg: ScenarioConfig, kk: int) -> int:
    # Static: every helper streams back-to-back, so M = R+K always certifies.
    # Under churn a helper's M packets can include losses — leave headroom.
    return kk if cfg.churn is None else 4 * kk


def _bucketed_horizon(cfg: ScenarioConfig, share: float, k: int) -> int:
    """~3x the fastest helper's fair share, bucketed to a power of two to
    limit jit recompiles across the R sweep, capped at _m_cap."""
    m = int(np.ceil(3.0 * k * share)) + 64
    bucket = 1 << int(np.ceil(np.log2(max(m, 64))))
    return min(bucket, _m_cap(cfg, k))


def _horizon(cfg: ScenarioConfig, mu, a, R: int) -> int:
    """Per-draw horizon for the sequential runner."""
    k = R + cfg.K(R)
    w = 1.0 / theory.shifted_exp_mean(np.asarray(a), np.asarray(mu))
    return _bucketed_horizon(cfg, float(w.max() / w.sum()), k)


def _horizon_shared(cfg: ScenarioConfig, R: int) -> int:
    """Key-independent horizon for the batched runner: the expected fastest
    helper's share from the mu/a choice set (certification re-runs with a
    doubled horizon when a draw lands above it)."""
    k = R + cfg.K(R)
    mu = np.asarray(cfg.mu_choices, dtype=np.float64)
    a = 1.0 / mu if cfg.a_mode == "inv_mu" else np.full_like(mu, cfg.a_const)
    w = 1.0 / theory.shifted_exp_mean(a, mu)
    return _bucketed_horizon(cfg, float(w.max() / (cfg.N * w.mean())), k)


# ---------------------------------------------------------------------------
# Top-level runners
# ---------------------------------------------------------------------------

def _run_mode(key, cfg: ScenarioConfig, R: int, mode: str,
              M_override: Optional[int] = None) -> Dict[str, np.ndarray]:
    k_h, _ = jax.random.split(key)
    mu, a, _rate = draw_helpers(k_h, cfg)
    kk = R + cfg.K(R)
    cap = _m_cap(cfg, kk)
    M = M_override if M_override is not None else _horizon(cfg, mu, a, R)
    for _ in range(8):  # grow horizon until the order statistic is certified
        out = _sim_one_jit(key, cfg, R, M, mode)
        if bool(out["valid"]) or M >= cap or M_override is not None:
            break
        M = min(M * 2, cap)
    res = {k: np.asarray(v) for k, v in out.items()}
    res["T"] = float(res["T"])
    res["M"] = M
    return res


def run_ccp(key, cfg: ScenarioConfig, R: int):
    return _run_mode(key, cfg, R, "ccp")


def run_best(key, cfg: ScenarioConfig, R: int):
    return _run_mode(key, cfg, R, "best")


def run_naive(key, cfg: ScenarioConfig, R: int):
    return _run_mode(key, cfg, R, "naive")


def run_naive_oracle(key, cfg: ScenarioConfig, R: int):
    """Naive stop-and-wait with the per-helper oracle ARQ timer (see
    :func:`_sim_one`) — only meaningful under churn."""
    return _run_mode(key, cfg, R, "naive_oracle")


# Default key schedule, recorded in bench JSON artifacts: PR-2 replaced the
# collision-prone ``PRNGKey(seed0 * 100003 + r)`` arithmetic (seed0=1,
# r=100003 collides with seed0=2, r=0, etc.) with ``fold_in`` over a root
# key, which is collision-free over the full (seed0, rep) space.  The value
# is a valid ``batch_keys(schedule=...)`` name; artifacts predating the
# switch carry no marker at all.
KEY_SCHEDULE = "fold_in"


def batch_keys(reps: int, seed0: int = 0,
               schedule: str = KEY_SCHEDULE) -> jnp.ndarray:
    """Per-rep PRNG keys: ``fold_in(PRNGKey(seed0), r)`` for rep r.

    ``schedule='legacy'`` is the compat shim reproducing the PR-1
    ``PRNGKey(seed0 * 100003 + r)`` arithmetic, which collides across
    ``(seed0, rep)`` pairs once ``reps`` approaches the 100003 stride
    (bench JSONs carry :data:`KEY_SCHEDULE` so runs are comparable)."""
    if schedule == "legacy":
        return jax.vmap(jax.random.PRNGKey)(seed0 * 100003 + jnp.arange(reps))
    if schedule != "fold_in":
        raise ValueError(f"unknown key schedule {schedule!r}")
    root = jax.random.PRNGKey(seed0)
    return jax.vmap(lambda r: jax.random.fold_in(root, r))(jnp.arange(reps))


@functools.lru_cache(maxsize=None)
def _sharded_batch_fn(cfg, R: int, M: int, mode: str, devs: tuple,
                      batch: int):
    """Jitted shard_map runner: the key batch is split over a 1-D 'data'
    mesh of ``devs`` and each device vmaps its shard through ``_sim_one``
    — per-rep lanes are independent, so no collectives and results are
    identical to the single-device vmap."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec

    from ..parallel import sharding as shd

    mesh = shd.data_mesh(devs)
    spec = shd.batch_spec(mesh, batch, extra_dims=1)
    body = lambda k: jax.vmap(lambda kk: _sim_one(kk, cfg, R, M, mode))(k)
    fn = shard_map(body, mesh=mesh, in_specs=(spec,),
                   out_specs=PartitionSpec("data"), check_rep=False)
    return jax.jit(fn)


def _sim_batch_sharded(keys, cfg: ScenarioConfig, R: int, M: int, mode: str,
                       devices=None):
    """Device-sharded batch: pad the key batch to a multiple of the device
    count (padding reps are discarded after the run) and shard it over the
    local device mesh."""
    devs = tuple(devices) if devices is not None else tuple(jax.local_devices())
    B = keys.shape[0]
    pad = (-B) % len(devs)
    keys_p = keys if pad == 0 else jnp.concatenate(
        [keys, jnp.broadcast_to(keys[-1:], (pad,) + keys.shape[1:])]
    )
    out = _sharded_batch_fn(cfg, R, M, mode, devs, keys_p.shape[0])(keys_p)
    return {k: v[:B] for k, v in out.items()}


def run_batch(keys, cfg: ScenarioConfig, R: int, mode: str,
              M_override: Optional[int] = None, shard: bool = False,
              devices=None) -> Dict[str, np.ndarray]:
    """Vmapped Monte-Carlo over a batch of PRNG keys (see module docstring).

    Returns a dict of stacked arrays: T (B,), valid (B,), efficiency (B, N),
    r_n, mu, a, rate, max_backoff, lost_frac (B, N), plus the shared horizon
    M actually used.  All reps share one bucketed horizon; if any rep's
    completion time is uncertified the horizon doubles and the batch re-runs.

    ``valid`` marks reps whose completion time is *certified*; when the
    horizon cap is hit under heavy churn, uncertified reps come back with
    ``valid=False`` and MUST be dropped (and counted) by the caller —
    ``benchmarks.common.mc_sim`` does this — never averaged.

    ``shard=True`` splits the key batch over ``devices`` (default: all
    local devices) via ``shard_map`` on a 1-D 'data' mesh, padding the
    batch up to a device-count multiple; results are identical to the
    unsharded vmap because per-rep lanes never communicate.
    """
    keys = jnp.asarray(keys)
    kk = R + cfg.K(R)
    cap = _m_cap(cfg, kk)
    M = M_override if M_override is not None else _horizon_shared(cfg, R)
    for _ in range(8):
        if shard:
            out = _sim_batch_sharded(keys, cfg, R, M, mode, devices)
        else:
            out = _sim_batch_jit(keys, cfg, R, M, mode)
        if bool(out["valid"].all()) or M >= cap or M_override is not None:
            break
        M = min(M * 2, cap)
    res = {k: np.asarray(v) for k, v in out.items()}
    res["M"] = M
    return res
