"""Vectorized discrete-event simulation of CCP and the paper's baselines.

Reproduces §6 of the paper: a collector offloads fountain-coded packets to
``N`` heterogeneous helpers over lossless links with random per-packet rates;
helper ``n`` computes packet ``i`` in ``beta_{n,i}`` (Scenario 1: i.i.d.
shifted-exponential per packet; Scenario 2: one draw per helper).  The
completion time is when the collector has received ``R+K`` computed packets.

Instead of a global event queue (O(N*R) sequential events), we exploit that
helpers only couple through the *stopping rule*: each helper's packet
timeline is an independent recurrence, so we

  1. scan each helper's timeline for ``M`` packets (vectorized over helpers,
     ``lax.scan`` over the packet index),
  2. merge the computed-packet arrival times ``Tr`` across helpers and take
     the (R+K)-th order statistic as the completion time.

The CCP send rule, eq. (8) ``TTI_i = min(Tr_i - Tx_i, E[beta])``, is *causal*
when read operationally:  ``tx_{i+1} = min(Tr_i, tx_i + E[beta])`` — send the
next packet either the moment the previous computed result returns (the
helper finished early) or when ``E[beta]`` has elapsed since the last send
(the cap), whichever happens first.  The ``E[beta]`` estimate in effect is
the latest one whose computed packet had returned by ``tx_i`` (held in a
small ring buffer).  Until the first computed packet returns the collector
has no estimate and falls back to stop-and-wait — this reproduces the
startup under-utilization the paper reports in §6 (Efficiency).

Timing model per packet (helper n, packet i):
  arrive_i = tx_i + d_up_i                      (uplink)
  start_i  = max(arrive_i, done_{i-1})          (FIFO helper queue)
  done_i   = start_i + beta_i
  Tr_i     = done_i + d_down_i                  (result downlink)
  RTTack_i = d_up_i + d_ack_i                   (receipt ACK, measured)
  idle_i   = max(0, arrive_i - done_{i-1})      (helper under-utilization)
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import ccp as ccp_mod
from . import theory

__all__ = [
    "ScenarioConfig",
    "draw_helpers",
    "draw_packet_tables",
    "simulate_stream",
    "completion_time",
    "run_ccp",
    "run_best",
    "run_naive",
    "RING",
]

RING = 16  # ring-buffer slots for in-flight (Tr, TTI) pairs


# ---------------------------------------------------------------------------
# Configuration and random draws
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ScenarioConfig:
    """Paper §6 simulation setup.

    scenario: 1 (i.i.d. per-packet runtimes / Model I) or
              2 (one runtime draw per helper / Model II).
    a_mode:   'const' -> a_n = a_const;  'inv_mu' -> a_n = 1/mu_n.
    mu_choices: helper speeds drawn uniformly from this set.
    rate_lo/rate_hi: per-helper mean link rate bounds (bits/sec); per-packet
      rates are Poisson with that mean (in Mbps), floored at 0.5 Mbps.
    """

    N: int = 100
    scenario: int = 1
    mu_choices: Tuple[float, ...] = (1.0, 2.0, 4.0)
    a_mode: str = "const"
    a_const: float = 0.5
    rate_lo: float = 10e6
    rate_hi: float = 20e6
    overhead: float = 0.05  # K = ceil(overhead * R)
    alpha: float = 0.25     # EWMA weight, eq. (4)

    def K(self, R: int) -> int:
        return int(np.ceil(self.overhead * R))

    def ccp_cfg(self, R: int) -> ccp_mod.CCPConfig:
        # Paper: Bx = 8R bits, Br = 8 bits, Back = 1 bit.
        return ccp_mod.CCPConfig(Bx=8.0 * R, Br=8.0, Back=1.0, alpha=self.alpha)


def draw_helpers(key, cfg: ScenarioConfig):
    """Draw per-helper (mu_n, a_n, mean link rate)."""
    k1, k2 = jax.random.split(key)
    mu = jax.random.choice(k1, jnp.asarray(cfg.mu_choices), shape=(cfg.N,))
    if cfg.a_mode == "const":
        a = jnp.full((cfg.N,), cfg.a_const)
    elif cfg.a_mode == "inv_mu":
        a = 1.0 / mu
    else:
        raise ValueError(f"unknown a_mode {cfg.a_mode!r}")
    rate = jax.random.uniform(k2, (cfg.N,), minval=cfg.rate_lo, maxval=cfg.rate_hi)
    return mu, a, rate


def draw_packet_tables(key, cfg: ScenarioConfig, mu, a, rate, M: int, R: int):
    """Per-packet tables, each (N, M): beta, d_up, d_ack, d_down."""
    kb, ku, kd = jax.random.split(key, 3)
    N = cfg.N
    if cfg.scenario == 1:
        beta = a[:, None] + jax.random.exponential(kb, (N, M)) / mu[:, None]
    elif cfg.scenario == 2:
        b = a + jax.random.exponential(kb, (N,)) / mu
        beta = jnp.broadcast_to(b[:, None], (N, M))
    else:
        raise ValueError(f"scenario must be 1 or 2, got {cfg.scenario}")
    # Per-packet link rates: Poisson around the per-helper mean (in Mbps),
    # floored to avoid div-by-zero on a zero draw.
    lam = jnp.broadcast_to((rate / 1e6)[:, None], (N, M))
    up = jnp.maximum(jax.random.poisson(ku, lam, (N, M)).astype(jnp.float32), 0.5) * 1e6
    dn = jnp.maximum(jax.random.poisson(kd, lam, (N, M)).astype(jnp.float32), 0.5) * 1e6
    c = cfg.ccp_cfg(R)
    d_up = c.Bx / up
    d_ack = c.Back / dn
    d_down = c.Br / dn
    return beta, d_up, d_ack, d_down


# ---------------------------------------------------------------------------
# The per-helper timeline scan
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("mode", "cfg_static"))
def simulate_stream(beta, d_up, d_ack, d_down, mode: str, cfg_static):
    """Simulate M packets on every helper. Returns dict of (N, M) arrays.

    mode: 'ccp'   — Algorithm 1 (estimated TTI, ring-buffer feedback delay)
          'best'  — oracle TTI_{n,i} = beta_{n,i} (paper's Best, eq. 13)
          'naive' — stop-and-wait: tx_{i+1} = Tr_i (paper's Naive, eq. 16)
    cfg_static: hashable (Bx, Br, Back, alpha) tuple.
    """
    Bx, Br, Back, alpha = cfg_static
    cfg = ccp_mod.CCPConfig(Bx=Bx, Br=Br, Back=Back, alpha=alpha)
    N, M = beta.shape
    state0 = ccp_mod.init_state(N)

    carry0 = dict(
        tx=jnp.zeros(N),              # send time of current packet (Tx_{n,1}=0)
        done_prev=jnp.zeros(N),
        tr_prev=jnp.zeros(N),
        est=state0,
        ring_tr=jnp.full((N, RING), jnp.inf),
        ring_tti=jnp.zeros((N, RING)),
    )
    xs = dict(
        beta=beta.T, d_up=d_up.T, d_ack=d_ack.T, d_down=d_down.T,
        i=jnp.arange(M),
    )

    def step(carry, x):
        tx = carry["tx"]
        arrive = tx + x["d_up"]
        start = jnp.maximum(arrive, carry["done_prev"])
        done = start + x["beta"]
        tr = done + x["d_down"]
        idle = jnp.maximum(arrive - carry["done_prev"], 0.0)
        rtt_ack = x["d_up"] + x["d_ack"]

        if mode == "ccp":
            est, _tti_i = ccp_mod.on_computed(
                carry["est"], cfg, tx, tr, carry["tr_prev"], rtt_ack,
                active=jnp.ones((N,), bool),
            )
            slot = x["i"] % RING
            ring_tr = carry["ring_tr"].at[:, slot].set(tr)
            ring_tti = carry["ring_tti"].at[:, slot].set(est.e_beta)
            # E[beta] estimate in effect when planning the next send: the
            # entry with the largest Tr among those with Tr <= tx (latest
            # information that had arrived by the current send instant).
            valid = ring_tr <= tx[:, None]
            masked = jnp.where(valid, ring_tr, -jnp.inf)
            sel = jnp.argmax(masked, axis=1)
            has = valid.any(axis=1)
            e_beta_sel = jnp.take_along_axis(ring_tti, sel[:, None], axis=1)[:, 0]
            # eq. (8), causal form: tx_{i+1} = min(Tr_i, tx_i + E[beta]).
            # Bootstrap: before any computed packet has returned by tx, the
            # collector has no estimate -> stop-and-wait on this packet.
            tx_next = jnp.where(has, jnp.minimum(tr, tx + e_beta_sel), tr)
        elif mode == "best":
            est = carry["est"]
            ring_tr, ring_tti = carry["ring_tr"], carry["ring_tti"]
            tx_next = tx + x["beta"]  # oracle: TTI_{n,i} = beta_{n,i}
        elif mode == "naive":
            est = carry["est"]
            ring_tr, ring_tti = carry["ring_tr"], carry["ring_tti"]
            tx_next = tr
        else:
            raise ValueError(mode)

        new_carry = dict(
            tx=tx_next, done_prev=done, tr_prev=tr, est=est,
            ring_tr=ring_tr, ring_tti=ring_tti,
        )
        out = dict(tr=tr, idle=idle, tx=tx, arrive=arrive)
        return new_carry, out

    _, outs = jax.lax.scan(step, carry0, xs)
    return {k: v.T for k, v in outs.items()}  # (N, M)


# ---------------------------------------------------------------------------
# Completion-time + efficiency extraction
# ---------------------------------------------------------------------------

def completion_time(tr: jnp.ndarray, k: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Time when the k-th computed packet reaches the collector.

    Returns (T, valid): ``valid`` is False if the per-helper horizon M was too
    short to certify T (some helper might have contributed more packets by T
    than were simulated) — caller should re-run with a larger M.
    """
    flat = jnp.sort(tr.reshape(-1))
    t = flat[k - 1]
    valid = t <= jnp.min(tr[:, -1])
    return t, valid


def efficiency_measured(tr, idle, beta, t_end) -> jnp.ndarray:
    """Paper §6 'Efficiency': 1 - sum(idle)/sum(beta) over packets the helper
    computed within the completion horizon. Returns (N,) per-helper values."""
    within = tr <= t_end
    idle_sum = (idle * within).sum(axis=1)
    busy_sum = (beta * within).sum(axis=1)
    return jnp.where(busy_sum > 0, 1.0 - idle_sum / (idle_sum + busy_sum), jnp.nan)


# ---------------------------------------------------------------------------
# Top-level runners (one Monte-Carlo rep each)
# ---------------------------------------------------------------------------

def _horizon(cfg: ScenarioConfig, mu, a, R: int) -> int:
    """Packets to simulate per helper: ~3x the fastest helper's fair share."""
    k = R + cfg.K(R)
    w = 1.0 / theory.shifted_exp_mean(np.asarray(a), np.asarray(mu))
    share = float(w.max() / w.sum())
    m = int(np.ceil(3.0 * k * share)) + 64
    # Bucket to limit jit recompiles across the R sweep.
    bucket = 1 << int(np.ceil(np.log2(max(m, 64))))
    return min(bucket, k)


def _run_mode(key, cfg: ScenarioConfig, R: int, mode: str) -> Dict[str, np.ndarray]:
    k_h, k_p = jax.random.split(key)
    mu, a, rate = draw_helpers(k_h, cfg)
    kk = R + cfg.K(R)
    M = _horizon(cfg, mu, a, R)
    for _ in range(6):  # grow horizon until the order statistic is certified
        beta, d_up, d_ack, d_down = draw_packet_tables(k_p, cfg, mu, a, rate, M, R)
        c = cfg.ccp_cfg(R)
        outs = simulate_stream(
            beta, d_up, d_ack, d_down, mode=mode,
            cfg_static=(c.Bx, c.Br, c.Back, c.alpha),
        )
        t, valid = completion_time(outs["tr"], kk)
        if bool(valid) or M >= kk:
            break
        M = min(M * 2, kk)
    eff = efficiency_measured(outs["tr"], outs["idle"], beta, t)
    r_n = (outs["tr"] <= t).sum(axis=1)
    return dict(
        T=float(t),
        efficiency=np.asarray(eff),
        r_n=np.asarray(r_n),
        mu=np.asarray(mu),
        a=np.asarray(a),
        rate=np.asarray(rate),
        M=M,
    )


def run_ccp(key, cfg: ScenarioConfig, R: int):
    return _run_mode(key, cfg, R, "ccp")


def run_best(key, cfg: ScenarioConfig, R: int):
    return _run_mode(key, cfg, R, "best")


def run_naive(key, cfg: ScenarioConfig, R: int):
    return _run_mode(key, cfg, R, "naive")
