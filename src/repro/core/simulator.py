"""Vectorized discrete-event simulation of CCP and the paper's baselines.

Reproduces §6 of the paper: a collector offloads fountain-coded packets to
``N`` heterogeneous helpers over links with random per-packet rates; helper
``n`` computes packet ``i`` in ``beta_{n,i}`` (Scenario 1: i.i.d.
shifted-exponential per packet; Scenario 2: one draw per helper).  The
completion time is when the collector has received ``R+K`` computed packets.

Instead of a global event queue (O(N*R) sequential events), we exploit that
helpers only couple through the *stopping rule*: each helper's packet
timeline is an independent recurrence, so we

  1. scan each helper's timeline for ``M`` packets (vectorized over helpers,
     ``lax.scan`` over the packet index),
  2. merge the computed-packet arrival times ``Tr`` across helpers and take
     the (R+K)-th order statistic as the completion time.

The CCP send rule, eq. (8) ``TTI_i = min(Tr_i - Tx_i, E[beta])``, is *causal*
when read operationally:  ``tx_{i+1} = min(Tr_i, tx_i + E[beta])`` — send the
next packet either the moment the previous computed result returns (the
helper finished early) or when ``E[beta]`` has elapsed since the last send
(the cap), whichever happens first.  The ``E[beta]`` estimate in effect is
the latest one whose computed packet had returned by ``tx_i`` (held in a
small ring buffer).  Until the first computed packet returns the collector
has no estimate and falls back to stop-and-wait — this reproduces the
startup under-utilization the paper reports in §6 (Efficiency).

Timing model per packet (helper n, packet i):
  arrive_i = tx_i + d_up_i                      (uplink)
  start_i  = max(arrive_i, done_{i-1})          (FIFO helper queue)
  done_i   = start_i + beta_i
  Tr_i     = done_i + d_down_i                  (result downlink)
  RTTack_i = d_up_i + d_ack_i                   (receipt ACK, measured)
  idle_i   = max(0, arrive_i - done_{i-1})      (helper under-utilization)

Dynamics / churn (beyond the paper's static Scenarios 1-2)
----------------------------------------------------------
``ScenarioConfig.churn = ChurnConfig(...)`` switches on a time-varying
resource model built from three loss processes plus a slowdown process.
Time is divided into phases of ``period`` seconds (``n_phases`` distinct
phases, wrapping around after ``n_phases * period`` seconds).

1. **Per-helper outages.**  With the default ``outage_dist='phase'`` a
   helper is independently *down* for whole phases with per-phase prob
   ``p_down`` (the PR-1 Bernoulli model).  With ``outage_dist='geometric'``
   or ``'lognormal'`` an outage *starts* at a phase boundary with prob
   ``p_down`` but lasts a sampled duration — geometric over whole periods
   (mean ``outage_mean``) or log-normal (mean ``outage_mean``, log-std
   ``outage_sigma``) — so downtime is bursty in time rather than
   memoryless per phase.  Packets that arrive (or would start computing)
   while the helper is down are lost.

2. **Gilbert–Elliott burst loss** (per helper, per packet).  A two-state
   Markov chain over packet indices: good -> bad with prob ``ge_p_bad``,
   bad -> good with prob ``ge_p_good``; a packet sent in the good state is
   lost with prob ``ge_loss_good``, in the bad state with ``ge_loss_bad``.
   The chain starts in its stationary distribution, so the marginal loss
   rate is ``pi_bad*ge_loss_bad + (1-pi_bad)*ge_loss_good`` with
   ``pi_bad = ge_p_bad / (ge_p_bad + ge_p_good)``.  This models bursty
   radio-link fades that i.i.d. ``drop_prob`` cannot express
   (cf. arXiv:2103.04247's correlated-erasure setting).

3. **Correlated whole-cell outages.**  With per-phase prob ``p_cell`` an
   outage *event* starts uniformly inside the phase; each helper belongs to
   the affected cell independently with prob ``cell_frac`` and every member
   is down simultaneously for the event's sampled duration (same duration
   distribution as (1); ``outage_dist='phase'`` means one full period).
   This takes correlated subsets of helpers down at once — the failure
   mode a per-helper model cannot produce.

On top, each packet is lost i.i.d. with prob ``drop_prob``, and a helper is
*degraded* per phase with prob ``p_slow`` (its service rate ``mu_n`` is
divided by ``slowdown``).  A lost packet never produces a ``Tr``; the
collector reacts with Algorithm 1 lines 13-14: the TTI backoff doubles
(``ccp.on_timeout``, capped at ``max_backoff``) and the retransmission
fires at the timeout deadline ``TO = 2*(TTI + RTT^data)``
(``ccp.timeout_deadline`` form).  A successful receipt resets the backoff,
so helpers that rejoin are re-ramped.  ``churn=None`` (default) runs the
exact static paper model, and a ``ChurnConfig`` with every loss knob at
zero is bit-for-bit identical to it.

Policy engine (PR 3, mode-string shims removed in PR 4)
-------------------------------------------------------
The per-mode logic that used to live in string branches here is now a set
of first-class :mod:`repro.core.policies` plugins driven by
:class:`repro.core.engine.Engine` — one scan, one vmapped/sharded
Monte-Carlo path for every policy (CCP, Best, Naive, the uncoded/HCMM
block baselines, the adaptive code-rate policy, and the decoder-in-the-loop
rateless policies).  Typical usage::

    from repro.core import engine, simulator
    keys = simulator.batch_keys(reps=40, seed0=0)
    res = engine.Engine().run(cfg, "ccp", keys, R=2000)
    res.T            # (reps,) completion times
    res.efficiency   # (reps, N) per-helper measured efficiency

The PR-2 mode-string surface (``run_batch(mode=...)``, ``run_ccp`` /
``run_best`` / ``run_naive`` / ``run_naive_oracle``,
``simulate_stream(mode=...)``) was deprecated in PR 3 and **removed** in
PR 4 once the pre-PR-3 benchmark artifacts were regenerated through the
engine; the golden-equivalence tests in ``tests/test_policies.py`` still
pin ``Engine.run`` bit-for-bit against the pre-redesign outputs.  This
module keeps the scenario model: configs, random draws
(``draw_helpers`` / ``draw_packet_tables`` / ``draw_dynamics``), the
completion/efficiency extraction, and ``batch_keys``.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import ccp as ccp_mod
from . import theory
from .policies.base import RING  # noqa: F401  (re-export: compat)

__all__ = [
    "ChurnConfig",
    "ScenarioConfig",
    "draw_helpers",
    "draw_packet_tables",
    "draw_packet_tables_fleet",
    "draw_dynamics",
    "draw_dynamics_fleet",
    "fleet_task_keys",
    "class_weights",
    "completion_time",
    "efficiency_measured",
    "batch_keys",
    "KEY_SCHEDULE",
    "RING",
]


# ---------------------------------------------------------------------------
# Configuration and random draws
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ChurnConfig:
    """Time-varying resource model (see module docstring for the three loss
    processes).

    period:     phase length in seconds; helper states re-randomize each
                phase, so ``period`` sets the churn timescale.
    n_phases:   distinct phases drawn; the schedule wraps (mod) beyond that.
    p_down:     per-phase prob a helper outage (packets sent to it are lost).
    p_slow:     per-phase prob a helper is degraded (mu_n / slowdown).
    slowdown:   service-rate divisor while degraded.
    drop_prob:  i.i.d. per-packet loss on top of outages.
    max_backoff: cap on the Alg.-1 line-13 multiplicative TTI backoff so a
                rejoining helper is re-probed within a bounded interval.
    outage_dist: outage-duration law for helper and cell outages — 'phase'
                (whole phases, the PR-1 Bernoulli model), 'geometric'
                (whole periods, mean ``outage_mean``) or 'lognormal'
                (continuous, mean ``outage_mean``, log-std ``outage_sigma``).
    outage_mean: mean outage duration in seconds for the duration laws.
    outage_sigma: log-std of the log-normal duration law.
    ge_p_bad:   Gilbert–Elliott good->bad transition prob per packet
                (0 disables the GE chain entirely).  Each ``ge_*`` knob is
                a scalar or a tuple of per-class values (heterogeneous GE:
                fast/slow faders in one cell) — tuples must share one
                length C and scalars broadcast; every helper is assigned a
                class uniformly at random in :func:`draw_dynamics`.
    ge_p_good:  GE bad->good transition prob per packet.
    ge_loss_good / ge_loss_bad: per-packet loss prob in each GE state.
    p_cell:     per-phase prob a correlated whole-cell outage event starts.
    cell_frac:  prob each helper belongs to a given cell event.
    rtt_dist:   feedback-RTT regime of the transport layer
                (:mod:`repro.core.transport`): 'off' (default — the
                idealized zero-latency control plane), 'fixed',
                'lognormal' (jittered) or 'cell' (latency spikes).  When
                enabled, every StepCtx observation the policy sees is
                delayed by the sampled feedback RTT (doubled when the ACK
                itself is lost and NACK-retransmitted) while ground-truth
                completion stays time-exact; ``rtt_mean = 0`` is
                bit-for-bit the idealized engine.
    rtt_mean:   mean feedback RTT in seconds.
    rtt_sigma:  log-std of the 'lognormal' per-packet jitter.
    rtt_spike_prob / rtt_spike_scale: 'cell' regime — per-packet prob of a
                latency spike and its multiplier on the base RTT.
    rtt_het:    per-helper base-RTT heterogeneity: bases are uniform in
                ``rtt_mean * [1 - rtt_het, 1 + rtt_het]``.
    """

    period: float = 5.0
    n_phases: int = 16
    p_down: float = 0.0
    p_slow: float = 0.0
    slowdown: float = 4.0
    drop_prob: float = 0.0
    max_backoff: float = 8.0
    outage_dist: str = "phase"
    outage_mean: float = 5.0
    outage_sigma: float = 0.5
    ge_p_bad: float | Tuple[float, ...] = 0.0
    ge_p_good: float | Tuple[float, ...] = 0.25
    ge_loss_good: float | Tuple[float, ...] = 0.0
    ge_loss_bad: float | Tuple[float, ...] = 1.0
    p_cell: float = 0.0
    cell_frac: float = 0.5
    rtt_dist: str = "off"
    rtt_mean: float = 0.0
    rtt_sigma: float = 0.5
    rtt_spike_prob: float = 0.05
    rtt_spike_scale: float = 10.0
    rtt_het: float = 0.0

    _GE_KNOBS = ("ge_p_bad", "ge_p_good", "ge_loss_good", "ge_loss_bad")

    def __post_init__(self):
        if self.outage_dist not in ("phase", "geometric", "lognormal"):
            raise ValueError(
                f"outage_dist must be 'phase', 'geometric' or 'lognormal', "
                f"got {self.outage_dist!r}"
            )
        from .transport import RTT_DISTS  # local: transport imports nothing back
        if self.rtt_dist not in RTT_DISTS:
            raise ValueError(
                f"rtt_dist must be one of {RTT_DISTS}, got {self.rtt_dist!r}"
            )
        if self.rtt_mean < 0.0:
            raise ValueError(f"rtt_mean must be >= 0, got {self.rtt_mean!r}")
        if not 0.0 <= self.rtt_het <= 1.0:
            raise ValueError(
                f"rtt_het must be in [0, 1], got {self.rtt_het!r}")
        # Normalize list-valued GE knobs to (hashable) tuples and check the
        # per-class lengths agree.
        lengths = set()
        for k in self._GE_KNOBS:
            v = getattr(self, k)
            if isinstance(v, list):
                v = tuple(v)
                object.__setattr__(self, k, v)
            if isinstance(v, tuple):
                lengths.add(len(v))
        if len(lengths) > 1:
            raise ValueError(
                f"tuple-valued ge_* knobs must share one class count, got "
                f"lengths {sorted(lengths)}"
            )

    @property
    def ge_classes(self) -> int:
        """Number of heterogeneous GE classes (1 = homogeneous)."""
        for k in self._GE_KNOBS:
            v = getattr(self, k)
            if isinstance(v, tuple):
                return len(v)
        return 1

    def ge_class_params(self) -> np.ndarray:
        """(4, C) per-class (p_bad, p_good, loss_good, loss_bad) array with
        scalar knobs broadcast across the C classes."""
        c = self.ge_classes
        return np.stack([
            np.broadcast_to(np.asarray(getattr(self, k), dtype=np.float64), (c,))
            for k in self._GE_KNOBS
        ])

    @property
    def ge_enabled(self) -> bool:
        return float(np.max(self.ge_p_bad)) > 0.0

    @property
    def cell_enabled(self) -> bool:
        return self.p_cell > 0.0

    @property
    def ge_stationary_bad(self) -> float:
        """Stationary P(bad) of the GE chain (0 when disabled); for
        heterogeneous classes, the uniform-over-classes average."""
        pb, pg, _, _ = self.ge_class_params()
        denom = pb + pg
        return float(np.mean(np.where(denom > 0, pb / np.where(denom > 0, denom, 1.0), 0.0)))

    @property
    def ge_loss_rate(self) -> float:
        """Stationary marginal per-packet GE loss rate (class-averaged)."""
        pb_t, pg, lg, lb = self.ge_class_params()
        denom = pb_t + pg
        pb = np.where(denom > 0, pb_t / np.where(denom > 0, denom, 1.0), 0.0)
        return float(np.mean(pb * lb + (1.0 - pb) * lg))

    @property
    def rtt_enabled(self) -> bool:
        """True when the transport feedback-delay line is structurally on
        (``rtt_dist != 'off'``); with ``rtt_mean = 0`` the enabled path is
        still numerically the idealized engine, bit for bit."""
        return self.rtt_dist != "off"

    @property
    def neutral(self) -> bool:
        return (self.p_down == 0.0 and self.p_slow == 0.0
                and self.drop_prob == 0.0 and not self.ge_enabled
                and not self.cell_enabled
                and (not self.rtt_enabled or self.rtt_mean == 0.0))

    def static_key(self) -> tuple:
        """Hashable tuple of the *structural* knobs the engine scan
        specializes on (the static ``churn_static`` argument of
        ``engine.policy_stream``)."""
        return (self.period, self.max_backoff, self.outage_dist,
                self.ge_enabled, self.cell_enabled, self.rtt_dist)


@dataclasses.dataclass(frozen=True)
class ScenarioConfig:
    """Paper §6 simulation setup.

    scenario: 1 (i.i.d. per-packet runtimes / Model I) or
              2 (one runtime draw per helper / Model II).
    a_mode:   'const' -> a_n = a_const;  'inv_mu' -> a_n = 1/mu_n.
    mu_choices: helper speeds drawn uniformly from this set.
    rate_lo/rate_hi: per-helper mean link rate bounds (bits/sec); per-packet
      rates are Poisson with that mean (in Mbps), floored at 0.5 Mbps.
    churn: optional :class:`ChurnConfig`; None reproduces the paper's static
      setup exactly.
    """

    N: int = 100
    scenario: int = 1
    mu_choices: Tuple[float, ...] = (1.0, 2.0, 4.0)
    a_mode: str = "const"
    a_const: float = 0.5
    rate_lo: float = 10e6
    rate_hi: float = 20e6
    overhead: float = 0.05  # K = ceil(overhead * R)
    alpha: float = 0.25     # EWMA weight, eq. (4)
    churn: Optional[ChurnConfig] = None

    def K(self, R: int) -> int:
        return int(np.ceil(self.overhead * R))

    def ccp_cfg(self, R: int) -> ccp_mod.CCPConfig:
        # Paper: Bx = 8R bits, Br = 8 bits, Back = 1 bit.
        return ccp_mod.CCPConfig(Bx=8.0 * R, Br=8.0, Back=1.0, alpha=self.alpha)


def draw_helpers(key, cfg: ScenarioConfig):
    """Draw per-helper (mu_n, a_n, mean link rate)."""
    k1, k2 = jax.random.split(key)
    mu = jax.random.choice(k1, jnp.asarray(cfg.mu_choices), shape=(cfg.N,))
    if cfg.a_mode == "const":
        a = jnp.full((cfg.N,), cfg.a_const)
    elif cfg.a_mode == "inv_mu":
        a = 1.0 / mu
    else:
        raise ValueError(f"unknown a_mode {cfg.a_mode!r}")
    rate = jax.random.uniform(k2, (cfg.N,), minval=cfg.rate_lo, maxval=cfg.rate_hi)
    return mu, a, rate


def draw_packet_tables(key, cfg: ScenarioConfig, mu, a, rate, M: int, R: int):
    """Per-packet tables, each (N, M): beta, d_up, d_ack, d_down."""
    kb, ku, kd = jax.random.split(key, 3)
    N = cfg.N
    if cfg.scenario == 1:
        beta = a[:, None] + jax.random.exponential(kb, (N, M)) / mu[:, None]
    elif cfg.scenario == 2:
        b = a + jax.random.exponential(kb, (N,)) / mu
        beta = jnp.broadcast_to(b[:, None], (N, M))
    else:
        raise ValueError(f"scenario must be 1 or 2, got {cfg.scenario}")
    # Per-packet link rates: Poisson around the per-helper mean (in Mbps),
    # floored to avoid div-by-zero on a zero draw.
    lam = jnp.broadcast_to((rate / 1e6)[:, None], (N, M))
    up = jnp.maximum(jax.random.poisson(ku, lam, (N, M)).astype(jnp.float32), 0.5) * 1e6
    dn = jnp.maximum(jax.random.poisson(kd, lam, (N, M)).astype(jnp.float32), 0.5) * 1e6
    c = cfg.ccp_cfg(R)
    d_up = c.Bx / up
    d_ack = c.Back / dn
    d_down = c.Br / dn
    return beta, d_up, d_ack, d_down


def fleet_task_keys(key, n_tasks: int):
    """(T, 2) per-task sub-keys with task 0 = ``key`` itself, so a 1-task
    fleet draws bit-for-bit the single-task tables (the equivalence spine
    of ``Engine.run_fleet``); extra tenants fold their task index into the
    same root key."""
    if n_tasks == 1:
        return key[None]
    extra = jnp.stack([jax.random.fold_in(key, 0x7A50 + t)
                       for t in range(1, n_tasks)])
    return jnp.concatenate([key[None], extra])


def draw_packet_tables_fleet(key, cfg: ScenarioConfig, mu, a, rate,
                             n_tasks: int, M: int, R: int):
    """Per-tenant packet tables, each (T, N, M).  Tenants share the helper
    draw (mu/a/rate — the fleet contends for ONE pool) but draw independent
    per-packet link/compute randomness."""
    ks = fleet_task_keys(key, n_tasks)
    return jax.vmap(
        lambda k: draw_packet_tables(k, cfg, mu, a, rate, M, R))(ks)


def draw_dynamics_fleet(key, cfg: ScenarioConfig, M: int, n_tasks: int):
    """Fleet churn tables: the *helper-state* processes (outage phases or
    intervals, slowdown phases, cell events, the Gilbert–Elliott chain
    state/transition draws) are drawn once and shared across tenants — a
    helper that is down is down for everyone — while the *per-packet*
    draws (``drop``, ``ge_u_loss``) gain a leading task axis (T, N, M),
    since tenants send distinct packets.  Task 0 reuses the single-task
    :func:`draw_dynamics` output bit-for-bit."""
    ks = fleet_task_keys(key, n_tasks)
    per = jax.vmap(lambda k: draw_dynamics(k, cfg, M))(ks)
    dyn = {k: v[0] for k, v in per.items()}
    dyn["drop"] = per["drop"]
    if "ge_u_loss" in per:
        dyn["ge_u_loss"] = per["ge_u_loss"]
    # Transport: the per-helper base RTT is a helper property (shared, from
    # task 0 like mu); per-packet jitter and ACK-loss uniforms are per
    # tenant — tenants send distinct packets over the same control path.
    for k in ("rtt_jit", "ack_u"):
        if k in per:
            dyn[k] = per[k]
    return dyn


def _draw_durations(key, ch: ChurnConfig, shape):
    """Outage durations (seconds) under ``ch.outage_dist``.

    'phase' -> exactly one period (the PR-1 whole-phase outage);
    'geometric' -> whole periods, Geometric(period/outage_mean), mean
    ``max(outage_mean, period)``; 'lognormal' -> continuous, mean
    ``outage_mean``, log-std ``outage_sigma``."""
    if ch.outage_dist == "geometric":
        p = min(1.0, ch.period / max(ch.outage_mean, ch.period))
        k = jax.random.geometric(key, p, shape)
        return k.astype(jnp.float32) * ch.period
    if ch.outage_dist == "lognormal":
        mu_log = np.log(ch.outage_mean) - 0.5 * ch.outage_sigma ** 2
        z = jax.random.normal(key, shape)
        return jnp.exp(mu_log + ch.outage_sigma * z)
    return jnp.full(shape, ch.period)


def draw_dynamics(key, cfg: ScenarioConfig, M: int):
    """Churn tables for one rep (see module docstring for the processes).

    Always: ``drop`` (N, M) i.i.d. per-packet loss and ``speed`` (N, P)
    per-phase service-rate factor (1 normal, 1/slowdown degraded).
    Per-helper outages: ``up`` (N, P) phase table when
    ``outage_dist='phase'``, else ``out_start``/``out_end`` (N, P) absolute
    intervals inside the wrapping window ``n_phases * period``.
    When enabled: ``cell_start``/``cell_end`` (P,) + ``cell_mask`` (N, P)
    correlated-outage events, and ``ge_bad0`` (N,) initial states +
    ``ge_u_trans``/``ge_u_loss`` (N, M) uniforms for the Gilbert–Elliott
    chain (its four probabilities ride along as traced values in
    ``ge_params`` — (4,) scalars, or (4, N) per-helper when any ``ge_*``
    knob is a per-class tuple: each helper draws a class uniformly, so one
    cell can mix fast and slow faders — so sweeping them does not
    retrace).
    When the transport layer is on (``rtt_dist != 'off'``):
    ``rtt_base`` (N,), ``rtt_jit``/``ack_u`` (N, M) and the traced
    ``ack_p_drop`` scalar (see :mod:`repro.core.transport.rtt`)."""
    ch = cfg.churn
    kd, ku, ks, kdur, kc, kg = jax.random.split(key, 6)
    N, P = cfg.N, ch.n_phases
    dyn = dict(
        drop=jax.random.bernoulli(kd, ch.drop_prob, (N, M)),
        speed=jnp.where(jax.random.bernoulli(ks, ch.p_slow, (N, P)),
                        1.0 / ch.slowdown, 1.0),
    )
    if ch.outage_dist == "phase":
        dyn["up"] = ~jax.random.bernoulli(ku, ch.p_down, (N, P))
    else:
        ev = jax.random.bernoulli(ku, ch.p_down, (N, P))
        start = jnp.broadcast_to(jnp.arange(P) * ch.period, (N, P))
        dur = _draw_durations(kdur, ch, (N, P))
        dyn["out_start"] = jnp.where(ev, start, jnp.inf)
        dyn["out_end"] = jnp.where(ev, start + dur, -jnp.inf)
    if ch.cell_enabled:
        ke, ko, kl, km = jax.random.split(kc, 4)
        ev = jax.random.bernoulli(ke, ch.p_cell, (P,))
        start = jnp.arange(P) * ch.period + \
            jax.random.uniform(ko, (P,)) * ch.period
        dur = _draw_durations(kl, ch, (P,))
        dyn["cell_start"] = jnp.where(ev, start, jnp.inf)
        dyn["cell_end"] = jnp.where(ev, start + dur, -jnp.inf)
        dyn["cell_mask"] = jax.random.bernoulli(km, ch.cell_frac, (N, P))
    if ch.ge_enabled:
        kb, kt, klo = jax.random.split(kg, 3)
        if ch.ge_classes == 1:
            dyn["ge_bad0"] = jax.random.bernoulli(
                kb, ch.ge_stationary_bad, (N,))
            dyn["ge_params"] = jnp.asarray([
                np.asarray(ch.ge_p_bad).item(),
                np.asarray(ch.ge_p_good).item(),
                np.asarray(ch.ge_loss_good).item(),
                np.asarray(ch.ge_loss_bad).item(),
            ])
        else:
            # Heterogeneous GE: each helper draws a fader class uniformly;
            # the chain starts in its per-helper stationary distribution.
            cls = jax.random.randint(
                jax.random.fold_in(kg, 0xFADE), (N,), 0, ch.ge_classes)
            per = jnp.asarray(ch.ge_class_params(), dtype=jnp.float32)[:, cls]
            pb, pg = per[0], per[1]
            denom = pb + pg
            stat = jnp.where(denom > 0, pb / jnp.where(denom > 0, denom, 1.0), 0.0)
            dyn["ge_bad0"] = jax.random.uniform(kb, (N,)) < stat
            dyn["ge_params"] = per  # (4, N)
        dyn["ge_u_trans"] = jax.random.uniform(kt, (N, M))
        dyn["ge_u_loss"] = jax.random.uniform(klo, (N, M))
    if ch.rtt_enabled:
        # Transport feedback-delay tables (repro.core.transport): drawn
        # from a key folded off the dynamics key so enabling the transport
        # layer never perturbs the churn tables above — the foundation of
        # the RTT=0 bit-for-bit guarantee.  ``ack_p_drop`` rides along as
        # a traced scalar so the ACK-loss floor never forces a retrace.
        from . import transport as transport_mod
        dyn.update(transport_mod.draw_rtt_tables(
            jax.random.fold_in(key, 0x577), ch, N, M))
        dyn["ack_p_drop"] = jnp.float32(ch.drop_prob)
    return dyn


# ---------------------------------------------------------------------------
# The per-helper timeline scan
# ---------------------------------------------------------------------------

def _phase_lookup(table, t, period: float):
    """table (N, P) indexed by the wrapping phase of times t (N,)."""
    P = table.shape[1]
    ph = (jnp.floor_divide(t, period).astype(jnp.int32) % P)[:, None]
    return jnp.take_along_axis(table, ph, axis=1)[:, 0]


def _interval_hit(start, end, t, window: float):
    """Per-interval membership of times t (N,) in [start, end) intervals,
    with the schedule wrapping every ``window`` seconds.  Returns (N, P).

    start/end are (N, P) per-helper intervals or (P,) shared event times
    (broadcast against the N axis).  Intervals are laid out in absolute
    time inside [0, window); an interval whose end spills past the window
    also covers the wrapped tail [0, end - window)."""
    tm = jnp.mod(t, window)[:, None]
    if start.ndim == 1:
        start, end = start[None, :], end[None, :]
    return ((tm >= start) & (tm < end)) | (tm < (end - window))


# ---------------------------------------------------------------------------
# Completion-time + efficiency extraction
# ---------------------------------------------------------------------------

def completion_time(tr: jnp.ndarray, k: int,
                    tx_end: Optional[jnp.ndarray] = None
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Time when the k-th computed packet reaches the collector.

    Returns (T, valid): ``valid`` is False if the per-helper horizon M was too
    short to certify T (some helper might have contributed more packets by T
    than were simulated) — caller should re-run with a larger M.  With
    ``tx_end`` (the send time of the first unsimulated packet, which under
    churn can be finite even when the last simulated Tr is inf) certification
    uses "no helper would even have *sent* packet M+1 by T".
    """
    flat = jnp.sort(tr.reshape(-1))
    t = flat[k - 1]
    if tx_end is not None:
        valid = jnp.isfinite(t) & (t <= jnp.min(tx_end))
    else:
        valid = t <= jnp.min(tr[:, -1])
    return t, valid


def efficiency_measured(tr, idle, beta, t_end) -> jnp.ndarray:
    """Paper §6 'Efficiency': 1 - sum(idle)/sum(beta) over packets the helper
    computed within the completion horizon. Returns (N,) per-helper values.

    The finiteness guard matters when ``t_end`` is +inf (a block-policy rep
    that can never complete): packets with ``tr = inf`` — lost or masked
    out of the block — must not count as computed."""
    within = jnp.isfinite(tr) & (tr <= t_end)
    idle_sum = (idle * within).sum(axis=1)
    busy_sum = (beta * within).sum(axis=1)
    return jnp.where(busy_sum > 0, 1.0 - idle_sum / (idle_sum + busy_sum), jnp.nan)


# ---------------------------------------------------------------------------
# Shared horizon heuristics (used by engine.Engine)
# ---------------------------------------------------------------------------

def _m_cap(cfg: ScenarioConfig, kk: int) -> int:
    # Static: every helper streams back-to-back, so M = R+K always certifies.
    # Under churn a helper's M packets can include losses — leave headroom.
    return kk if cfg.churn is None else 4 * kk


def _bucketed_horizon(cfg: ScenarioConfig, share: float, k: int) -> int:
    """~3x the fastest helper's fair share, bucketed to a power of two to
    limit jit recompiles across the R sweep, capped at _m_cap."""
    m = int(np.ceil(3.0 * k * share)) + 64
    bucket = 1 << int(np.ceil(np.log2(max(m, 64))))
    return min(bucket, _m_cap(cfg, k))


def _horizon(cfg: ScenarioConfig, mu, a, R: int) -> int:
    """Per-draw horizon for the sequential runner."""
    k = R + cfg.K(R)
    w = 1.0 / theory.shifted_exp_mean(np.asarray(a), np.asarray(mu))
    return _bucketed_horizon(cfg, float(w.max() / w.sum()), k)


def class_weights(cfg: ScenarioConfig):
    """Per-mu-class ``(mu, a, 1/E[beta])`` arrays from the choice set — the
    one place the ``a_mode`` mapping lives for horizon heuristics (shared
    by :func:`_horizon_shared` and the block policies' ``horizon_hint``)."""
    mu = np.asarray(cfg.mu_choices, dtype=np.float64)
    a = 1.0 / mu if cfg.a_mode == "inv_mu" else np.full_like(mu, cfg.a_const)
    return mu, a, 1.0 / theory.shifted_exp_mean(a, mu)


def _horizon_shared(cfg: ScenarioConfig, R: int) -> int:
    """Key-independent horizon for the batched runner: the expected fastest
    helper's share from the mu/a choice set (certification re-runs with a
    doubled horizon when a draw lands above it)."""
    k = R + cfg.K(R)
    _mu, _a, w = class_weights(cfg)
    return _bucketed_horizon(cfg, float(w.max() / (cfg.N * w.mean())), k)


# Default key schedule, recorded in bench JSON artifacts: PR-2 replaced the
# collision-prone ``PRNGKey(seed0 * 100003 + r)`` arithmetic (seed0=1,
# r=100003 collides with seed0=2, r=0, etc.) with ``fold_in`` over a root
# key, which is collision-free over the full (seed0, rep) space.  The value
# is a valid ``batch_keys(schedule=...)`` name; artifacts predating the
# switch carry no marker at all.
KEY_SCHEDULE = "fold_in"


def batch_keys(reps: int, seed0: int = 0,
               schedule: str = KEY_SCHEDULE) -> jnp.ndarray:
    """Per-rep PRNG keys: ``fold_in(PRNGKey(seed0), r)`` for rep r.

    ``schedule='legacy'`` is the compat shim reproducing the PR-1
    ``PRNGKey(seed0 * 100003 + r)`` arithmetic, which collides across
    ``(seed0, rep)`` pairs once ``reps`` approaches the 100003 stride
    (bench JSONs carry :data:`KEY_SCHEDULE` so runs are comparable)."""
    if schedule == "legacy":
        warnings.warn(
            "batch_keys(schedule='legacy') reproduces the collision-prone "
            "PR-1 key arithmetic and is deprecated; use the default "
            "'fold_in' schedule",
            DeprecationWarning, stacklevel=2,
        )
        return jax.vmap(jax.random.PRNGKey)(seed0 * 100003 + jnp.arange(reps))
    if schedule != "fold_in":
        raise ValueError(f"unknown key schedule {schedule!r}")
    root = jax.random.PRNGKey(seed0)
    return jax.vmap(lambda r: jax.random.fold_in(root, r))(jnp.arange(reps))
