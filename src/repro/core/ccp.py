"""Computation Control Protocol (CCP) — Algorithm 1 of the paper.

The collector cannot observe per-packet compute times ``beta_{n,i}``; it only
sees packet send times and ACK arrival times.  CCP estimates ``E[beta]`` per
helper from that information and drives the transmission interval ``TTI`` to
it (eq. 8), with TCP-style multiplicative backoff on timeout.

Everything here is written as *pure, vectorized state-update functions over
per-helper arrays* so the exact same arithmetic is used by

  * :mod:`repro.core.simulator` — the paper-faithful discrete-event
    reproduction (Scenarios 1 & 2, Figs. 3-5), and
  * :mod:`repro.core.scheduler` — the TPU runtime scheduler, where the
    "helpers" are devices/hosts and the ACK timestamps are step-time
    telemetry.

Paper equation map:
  eq. (2)  XTT_{n,i+1} = Tr_{n,i} - Tx_{n,i+1}          (residual time)
  eq. (3)  RTT^data    = (Bx+Br)/(Bx+Back) * RTT^ack    (size rescale)
  eq. (4)  RTT^data    <- alpha*sample + (1-alpha)*ewma (EWMA)
  eq. (5)  E[beta]     = (Tc - Tu) / m                  (busy time / packets)
  eq. (6)  Tc          = Tr - Br/(Bx+Br) * RTT^data     (finish-time estimate)
  eq. (7)  Tu          <- Tu + max(0, RTT^data - XTT)   (under-utilization)
  eq. (8)  TTI         = min(Tr - Tx, E[beta])
  l.13-14  timeout: TTI <- 2*TTI ; TO = 2*(TTI + RTT^data)
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = ["CCPConfig", "CCPState", "init_state", "on_computed", "on_timeout",
           "tti", "timeout_deadline", "arq_timeout"]


@dataclasses.dataclass(frozen=True)
class CCPConfig:
    """Packet-size and smoothing constants (paper §6 defaults).

    Bx is the transmitted-packet size in bits (8R in the paper: one byte per
    matrix entry per row), Br the computed-result size, Back the ACK size.
    ``alpha`` is the EWMA weight of eq. (4); the paper does not pin it — we
    default to 0.25 (between TCP's 1/8 and a fast-adapting 1/2) and expose it.
    """

    Bx: float
    Br: float = 8.0
    Back: float = 1.0
    alpha: float = 0.25

    @property
    def data_scale(self) -> float:
        """eq. (3): RTT^data / RTT^ack."""
        return (self.Bx + self.Br) / (self.Bx + self.Back)

    @property
    def back_frac(self) -> float:
        """eq. (6): backward-trip fraction of RTT^data."""
        return self.Br / (self.Bx + self.Br)

    @property
    def fwd_frac(self) -> float:
        """Alg. 1 line 7: forward-trip fraction of RTT^ack."""
        return self.Bx / (self.Bx + self.Back)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CCPState:
    """Per-helper estimator state; every field is an (N,)-array."""

    rtt_data: jnp.ndarray  # EWMA of eq. (4)
    Tu: jnp.ndarray        # cumulative under-utilization estimate, eq. (7)
    m: jnp.ndarray         # packets processed (int)
    e_beta: jnp.ndarray    # eq. (5)
    tti_backoff: jnp.ndarray  # multiplicative factor from timeouts (l.13)

    def replace(self, **kw) -> "CCPState":
        return dataclasses.replace(self, **kw)


def init_state(n: int, dtype=jnp.float32) -> CCPState:
    return CCPState(
        rtt_data=jnp.zeros(n, dtype),
        Tu=jnp.zeros(n, dtype),
        m=jnp.zeros(n, jnp.int32),
        e_beta=jnp.zeros(n, dtype),
        tti_backoff=jnp.ones(n, dtype),
    )


def on_computed(
    state: CCPState,
    cfg: CCPConfig,
    tx_i: jnp.ndarray,
    tr_i: jnp.ndarray,
    tr_prev: jnp.ndarray,
    rtt_ack: jnp.ndarray,
    active: jnp.ndarray,
) -> Tuple[CCPState, jnp.ndarray]:
    """Process the computed-packet receipt for one packet per helper.

    All args are (N,) arrays; ``active`` masks helpers whose update applies.
    ``tr_prev`` is Tr_{n,i-1} (ignored for the first packet). Returns the new
    state and TTI_{n,i} per eq. (8).
    """
    first = state.m == 0
    rtt_sample = cfg.data_scale * rtt_ack
    rtt_new = jnp.where(
        first, rtt_sample, cfg.alpha * rtt_sample + (1.0 - cfg.alpha) * state.rtt_data
    )
    # eq. (2)/(7): XTT_i = Tr_{i-1} - Tx_i ; Tu += max(0, RTT - XTT)
    xtt = tr_prev - tx_i
    tu_inc = jnp.maximum(0.0, rtt_new - xtt)
    tu_new = jnp.where(first, cfg.fwd_frac * rtt_ack, state.Tu + tu_inc)
    m_new = state.m + 1
    # eq. (6): helper-side finish-time estimate.
    tc = tr_i - cfg.back_frac * rtt_new
    # eq. (5).
    e_beta = jnp.maximum((tc - tu_new) / m_new.astype(tc.dtype), 1e-9)
    # Successful receipt resets the timeout backoff (ACK arrived in time).
    new_state = CCPState(
        rtt_data=jnp.where(active, rtt_new, state.rtt_data),
        Tu=jnp.where(active, tu_new, state.Tu),
        m=jnp.where(active, m_new, state.m),
        e_beta=jnp.where(active, e_beta, state.e_beta),
        tti_backoff=jnp.where(active, 1.0, state.tti_backoff),
    )
    tti_i = jnp.minimum(tr_i - tx_i, e_beta) * new_state.tti_backoff
    return new_state, tti_i


def on_timeout(state: CCPState, active: jnp.ndarray,
               max_backoff: float | None = None) -> CCPState:
    """Alg. 1 line 13: double the effective TTI of unresponsive helpers.

    ``max_backoff`` caps the multiplicative factor so a helper that drops out
    for a long stretch is still re-probed at a bounded interval and its
    rejoin is detected (the paper leaves the cap unspecified; the simulator
    passes its churn-model cap, the runtime scheduler may pass None).
    """
    doubled = state.tti_backoff * 2.0
    if max_backoff is not None:
        doubled = jnp.minimum(doubled, max_backoff)
    return state.replace(
        tti_backoff=jnp.where(active, doubled, state.tti_backoff)
    )


def tti(state: CCPState, tr_minus_tx: jnp.ndarray) -> jnp.ndarray:
    """eq. (8) with the current estimate and the last observed Tr - Tx."""
    return jnp.minimum(tr_minus_tx, state.e_beta) * state.tti_backoff


def timeout_deadline(state: CCPState, tti_cur: jnp.ndarray) -> jnp.ndarray:
    """Alg. 1 line 14: TO = 2 * (TTI + RTT^data)."""
    return 2.0 * (tti_cur + state.rtt_data)


def arq_timeout(beta_mean, rtt_data) -> jnp.ndarray:
    """Alg.-1-line-14-shaped retransmission timeout for estimator-free
    stop-and-wait baselines: TO = 2 * (E[beta] + RTT^data), with E[beta]
    supplied externally (worst-case class for the paper's Naive, the true
    per-helper mean for the oracle-timer variant) instead of eq. (5)."""
    return 2.0 * (beta_mean + rtt_data)
