"""The paper's primary contribution: Computation Control Protocol (CCP) —
fountain-coded cooperative computation with dynamic, heterogeneity-aware
task allocation — plus its TPU-native realizations (coded matmul, coded
gradient aggregation, CCP-driven scheduling).

Simulation entry point: :class:`repro.core.engine.Engine` drives any
registered :mod:`repro.core.policies` plugin (ccp / best / naive /
naive_oracle / uncoded_* / hcmm / adaptive_rate) through one vmapped,
optionally device-sharded Monte-Carlo path."""

from . import (baselines, ccp, engine, fleet, fountain, policies,  # noqa: F401
               simulator, theory)
