"""The paper's primary contribution: Computation Control Protocol (CCP) —
fountain-coded cooperative computation with dynamic, heterogeneity-aware
task allocation — plus its TPU-native realizations (coded matmul, coded
gradient aggregation, CCP-driven scheduling)."""

from . import baselines, ccp, fountain, simulator, theory  # noqa: F401
