"""Decoder-in-the-loop: incremental LT peeling decode inside the engine scan.

The paper's C3P argues an O(R) Raptor decode cost, but a simulator that
*counts* packets (completion = the (R+K)-th order statistic) never actually
decodes — LT overhead randomness is invisible and a policy cannot shed
redundancy when the decode finishes early.  This subsystem closes that loop
with a scan/vmap-safe incremental peeling decoder
(:mod:`repro.core.decode.peeling`):

* ``DecoderState`` — per-source recovered mask, parity residual-degree
  table, ripple/decoded counters — a plain dict pytree carried through the
  engine's per-packet ``lax.scan``.
* ``absorb`` / ``peel_round`` / ``peel`` — pure jnp fixpoint peeling, the
  online mirror of :func:`repro.core.fountain.peel_decode_plan`.
* ``decode_completion`` — the *time-exact* completion rule: binary search
  over the time-sorted arrival prefix for the first decodable subset
  (peeling success is monotone in the received set, so the search is exact).

Payload-level decoding lives in :mod:`repro.kernels.lt_decode` (a batched
masked gather + subtract peel-round Pallas kernel over the round-levelized
:func:`repro.core.fountain.plan_rounds` schedule).
"""

from .peeling import (  # noqa: F401
    DEC_DMAX,
    DEC_SEED,
    DecoderTables,
    absorb,
    decode_completion,
    decoder_aux,
    finalize_decode,
    init_state,
    make_decoder_code,
    make_tables,
    offline_overhead_samples,
    peel,
    peel_round,
    send_order_ids,
    slot_ids,
)

__all__ = [
    "DEC_DMAX",
    "DEC_SEED",
    "DecoderTables",
    "absorb",
    "decode_completion",
    "decoder_aux",
    "finalize_decode",
    "init_state",
    "make_decoder_code",
    "make_tables",
    "offline_overhead_samples",
    "peel",
    "peel_round",
    "send_order_ids",
    "slot_ids",
]
