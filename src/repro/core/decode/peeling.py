"""Scan/vmap-safe incremental LT peeling decoder.

The offline decoder (:func:`repro.core.fountain.peel_decode_plan`) walks the
residual graph with Python sets — exact, but host-side and per-received-set.
This module is the *online* mirror: fixed-shape jnp arrays and pure
functions, so the decode state can ride the engine's per-packet ``lax.scan``
carry, vmapped over Monte-Carlo reps and device-sharded, with zero host
round-trips.

Representation
--------------
The code is the systematic LT construction of :func:`fountain.make_lt_code`
with a *parity pool* of ``P`` rows (`make_decoder_code`).  Ids ``g < R``
are the source blocks themselves and ids ``g >= R`` map onto pool row
``(g - R) % P`` (wrapping past the pool resends an earlier parity; the
absorb is idempotent, so duplicates are harmless and simply useless, like a
repeated fountain symbol).  Symbol ids follow the master's *send counter*:
whichever helper sends next gets the next unissued id, so the ids on the
wire are always a dense prefix of the pool's designed order and a straggler
never strands a block of unsent ids.  The exact assignment is the rank of
the send instant over the whole trace (:func:`send_order_ids`, used by
``finalize_decode``); the in-scan decoder state uses the per-round
approximation (``engine._send_time_ids``, recorded in ``outs["sym_id"]``)
because a forward round-major scan cannot know how many future-round sends
precede a straggler's current send in wall-clock time.  The legacy
round-robin assignment — helper ``n``'s packet ``i`` carries ``g = i*N + n``
(`slot_ids`) — remains the ``ids=None`` fallback of
:func:`decode_completion`.

``DecoderState`` (a plain dict pytree, one per Monte-Carlo rep):

==============  =========  ==================================================
``recovered``   (R,) bool  per-source-block recovered mask
``rx``          (P,) bool  which parity-pool rows have arrived
``res_deg``     (P,) i32   residual degree of every pool row = #unrecovered
                           neighbours (maintained for all rows, received or
                           not, so a newly arrived row is peelable instantly)
``count``       () i32     ``recovered.sum()``
``ripple``      () i32     sources released by peeling in the last absorb
``done``        () bool    ``count == R``
==============  =========  ==================================================

``absorb`` folds one batch of arrivals in and runs ``peel`` to the fixpoint
(a ``lax.while_loop``; each round releases every received row of residual
degree 1 at once).  Peeling to fixpoint is a monotone closure of the
received set, so the final recovered mask is independent of arrival order —
exactly the set the offline planner recovers (pinned by
``tests/test_decode.py``).

``decode_completion`` turns the (N, M) result-arrival table into the honest
completion time: the decodable-prefix property is monotone in the
time-sorted arrival prefix, so a binary search over the prefix length finds
the *first instant* at which the collector's received set decodes — the
quantity a packet counter can only approximate.
"""

from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .. import fountain

__all__ = [
    "DEC_DMAX",
    "DEC_SEED",
    "DecoderTables",
    "absorb",
    "decode_completion",
    "decoder_aux",
    "finalize_decode",
    "init_state",
    "make_decoder_code",
    "make_tables",
    "send_order_ids",
    "offline_overhead_samples",
    "peel",
    "peel_round",
    "slot_ids",
]

#: Neighbour-slot cap for the in-loop tables: robust-soliton degrees are
#: overwhelmingly small and :func:`fountain.make_lt_code` trims the rare
#: heavy rows coverage-aware, so 16 slots keep the per-step peel cost at
#: O(P * 16) without hurting decodability at simulator block counts.
DEC_DMAX = 16

#: Pool-construction seed.  The code is *shared across Monte-Carlo reps*
#: (like a real deployment's task-id-seeded pseudo-random code): the pool is
#: built host-side from static ints in ``prepare`` and closed over by the
#: trace, so it costs one constant, not a per-rep table.
DEC_SEED = 0xDEC0DE

DecoderTables = dict  # {"idx": (P, d_max) int32, "mask": (P, d_max) bool}


def _cover_order(idx: np.ndarray, mask: np.ndarray, R: int) -> np.ndarray:
    """Permutation of the parity rows into successive greedy covers.

    The rateless stream emits pool rows in order, so the rows a decoder sees
    *first* matter most: with soliton-random ordering the expected coverage
    of a straggling source by the first ``B`` rows is only ``B * E[deg] / R``
    and the decode tail stalls waiting for a parity that touches it.
    Re-ordering the pool as cover after cover (each pass sweeps the
    remaining rows, keeping those that touch a source the pass has not
    covered yet) guarantees every source is touched within ~``R/E[deg]``
    emitted parities per pass — the overhead tail collapses while the
    *set* of pool rows (and hence the code) is unchanged.
    """
    P = idx.shape[0]
    sets = [idx[p, mask[p]] for p in range(P)]
    remaining = list(range(P))
    order: list = []
    while remaining:
        covered = np.zeros(R, bool)
        deferred = []
        for p in remaining:
            if not covered.all() and not covered[sets[p]].all():
                covered[sets[p]] = True
                order.append(p)
            else:
                deferred.append(p)
        if len(deferred) == len(remaining):  # no progress possible
            order.extend(deferred)
            break
        remaining = deferred
    return np.asarray(order, dtype=np.int64)


@functools.lru_cache(maxsize=64)
def make_decoder_code(R: int, K_pool: Optional[int] = None, *,
                      seed: int = DEC_SEED,
                      d_max: int = DEC_DMAX) -> fountain.LTCode:
    """Systematic LT code with a parity pool sized for in-loop decoding.

    ``K_pool`` defaults to ``max(R, 64)``: enough distinct parities that the
    rateless stream keeps producing *fresh* symbols up to ~50% effective
    loss before the pool wraps into duplicates.  The pool rows are permuted
    into successive greedy covers (:func:`_cover_order`) so the earliest
    emitted parities already touch every source — the Raptor-flavoured fix
    for the small-R soliton overhead tail.

    Memoized: every input is a static int and ``prepare`` runs inside the
    trace, so without the cache each compile variant (policy x churn config
    x horizon doubling) would re-run the host-side pool construction.
    Callers must treat the returned (numpy-backed) code as immutable.
    """
    if K_pool is None:
        K_pool = max(R, 64)
    code = fountain.make_lt_code(R, K_pool, seed=seed, d_max=d_max)
    perm = _cover_order(code.idx[R:], code.mask[R:], R)
    sl = np.concatenate([np.arange(R), R + perm])
    return fountain.LTCode(idx=code.idx[sl], mask=code.mask[sl],
                           coef=code.coef[sl], R=R, K=code.K)


def make_tables(code: fountain.LTCode) -> DecoderTables:
    """Parity-pool neighbour tables (the systematic prefix is implicit)."""
    return {
        "idx": jnp.asarray(code.idx[code.R:], jnp.int32),
        "mask": jnp.asarray(code.mask[code.R:], bool),
    }


def decoder_aux(R: int, **code_kw) -> dict:
    """The ``aux["decoder"]`` pytree a ``uses_decoder`` policy's ``prepare``
    must hand the engine (see ``policies/base.py``): pool tables + zero
    state, built host-side once from the static ``R``."""
    tables = make_tables(make_decoder_code(R, **code_kw))
    return {"tables": tables, "state0": init_state(R, tables)}


def init_state(R: int, tables: DecoderTables) -> dict:
    deg = tables["mask"].sum(axis=1).astype(jnp.int32)
    P = tables["idx"].shape[0]
    return dict(
        recovered=jnp.zeros((R,), bool),
        rx=jnp.zeros((P,), bool),
        res_deg=deg,
        count=jnp.int32(0),
        ripple=jnp.int32(0),
        done=jnp.asarray(False),
    )


def slot_ids(i, n: int) -> jnp.ndarray:
    """Global coded id of each helper's packet at scan step ``i``: the
    collector hands out fresh symbols round-robin across helpers."""
    return i * n + jnp.arange(n, dtype=jnp.int32)


def _deg_drop(tables: DecoderTables, new_src: jnp.ndarray) -> jnp.ndarray:
    """Per-pool-row count of neighbours newly recovered (``new_src`` (R,))."""
    return (tables["mask"] & new_src[tables["idx"]]).sum(axis=1).astype(jnp.int32)


def peel_round(recovered, res_deg, rx, tables):
    """One peel round: every received row of residual degree 1 releases its
    unique unrecovered neighbour.  Returns (recovered, res_deg, released)."""
    rel = rx & (res_deg == 1)
    cand = tables["mask"] & ~recovered[tables["idx"]]  # (P, d_max)
    new_src = (
        jnp.zeros_like(recovered).at[tables["idx"]].max(cand & rel[:, None])
    )
    recovered = recovered | new_src
    res_deg = res_deg - _deg_drop(tables, new_src)
    return recovered, res_deg, new_src.sum().astype(jnp.int32)


def peel(state: dict, tables: DecoderTables) -> dict:
    """Peel to the fixpoint (no received row left at residual degree 1)."""
    rx = state["rx"]

    def cond(carry):
        recovered, res_deg, _ = carry
        return (rx & (res_deg == 1)).any()

    def body(carry):
        recovered, res_deg, released = carry
        recovered, res_deg, n = peel_round(recovered, res_deg, rx, tables)
        return recovered, res_deg, released + n

    recovered, res_deg, released = jax.lax.while_loop(
        cond, body, (state["recovered"], state["res_deg"], jnp.int32(0))
    )
    count = recovered.sum().astype(jnp.int32)
    return dict(
        state, recovered=recovered, res_deg=res_deg, count=count,
        ripple=released, done=count == recovered.shape[0],
    )


def absorb(state: dict, tables: DecoderTables, ids, received) -> dict:
    """Fold a batch of arrivals (global ids ``ids`` (n,), arrival mask
    ``received`` (n,)) into the state and peel to the fixpoint.

    Idempotent per id: duplicate systematic copies and pool-wrapped parity
    resends are no-ops, so callers never need to dedupe."""
    R = state["recovered"].shape[0]
    P = tables["idx"].shape[0]
    ids = ids.astype(jnp.int32)
    is_sys = ids < R
    rec0 = state["recovered"]
    recovered = rec0.at[jnp.clip(ids, 0, R - 1)].max(received & is_sys)
    new_src = recovered & ~rec0
    pid = jnp.clip(jnp.mod(ids - R, P), 0, P - 1)
    rx = state["rx"].at[pid].max(received & ~is_sys)
    res_deg = state["res_deg"] - _deg_drop(tables, new_src)
    return peel(dict(state, recovered=recovered, rx=rx, res_deg=res_deg),
                tables)


# ---------------------------------------------------------------------------
# Time-exact decode completion (the honest replacement for the packet count)
# ---------------------------------------------------------------------------

def _closure_success(rec0, rx, tables, deg) -> jnp.ndarray:
    """Peel a from-scratch received set to its fixpoint; True iff it decodes."""
    res0 = deg - _deg_drop(tables, rec0)

    def cond(carry):
        recovered, res_deg = carry
        return ((rx & (res_deg == 1)).any()) & ~recovered.all()

    def body(carry):
        recovered, res_deg = carry
        recovered, res_deg, _ = peel_round(recovered, res_deg, rx, tables)
        return recovered, res_deg

    recovered, _ = jax.lax.while_loop(cond, body, (rec0, res0))
    return recovered.all()


def decode_completion(
    tr: jnp.ndarray,
    tables: DecoderTables,
    R: int,
    tx_end: Optional[jnp.ndarray] = None,
    ids: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Exact decode-success completion time from the (N, M) arrival table.

    Sorts all result arrivals by time and binary-searches the shortest
    prefix whose coded-id set peels to a full decode (success is monotone in
    the prefix, so the search is exact: the collector, decoding eagerly as
    results arrive, finishes at precisely ``T``).  Returns ``(T, valid,
    k_star)`` — ``k_star`` the number of result arrivals consumed, so
    ``k_star - R`` is the *measured* LT overhead of this rep; ``valid``
    applies the same horizon certification as
    :func:`repro.core.simulator.completion_time` and is False when even the
    full horizon's arrivals cannot decode (caller re-runs with a larger M).

    ``ids`` is the (N, M) global coded id each slot carried.  ``None``
    reproduces the legacy round-robin assignment ``g = i*N + n``; the
    engine now records the send-time assignment in ``outs["sym_id"]``
    (fresh ids handed to whichever helper sends next), which closes the
    counter-vs-decode gap a slow helper opens by sitting on an early
    systematic id.
    """
    N, M = tr.shape
    P = tables["idx"].shape[0]
    nm = N * M
    deg = tables["mask"].sum(axis=1).astype(jnp.int32)
    if ids is None:
        ids = (jnp.arange(M, dtype=jnp.int32)[None, :] * N
               + jnp.arange(N, dtype=jnp.int32)[:, None])
    ids = ids.astype(jnp.int32)
    flat_tr = tr.reshape(-1)
    order = jnp.argsort(flat_tr)
    st_tr = flat_tr[order]
    st_ids = ids.reshape(-1)[order]
    n_fin = jnp.isfinite(flat_tr).sum().astype(jnp.int32)
    is_sys = st_ids < R
    sid = jnp.clip(st_ids, 0, R - 1)
    pid = jnp.clip(jnp.mod(st_ids - R, P), 0, P - 1)
    pos = jnp.arange(nm, dtype=jnp.int32)

    def success(k):
        take = pos < k
        rec0 = jnp.zeros((R,), bool).at[sid].max(take & is_sys)
        rx = jnp.zeros((P,), bool).at[pid].max(take & ~is_sys)
        return _closure_success(rec0, rx, tables, deg)

    ok_all = success(n_fin)
    iters = int(math.ceil(math.log2(max(nm, 2)))) + 2

    def body(_, lohi):
        lo, hi = lohi
        mid = (lo + hi) // 2
        s = success(mid)
        return jnp.where(s, lo, mid + 1), jnp.where(s, mid, hi)

    lo0 = jnp.int32(min(R, nm))  # fewer than R arrivals can never decode
    k_star = jax.lax.fori_loop(0, iters, body, (lo0, n_fin))[1]
    t = jnp.where(ok_all, st_tr[jnp.clip(k_star - 1, 0, nm - 1)], jnp.inf)
    if tx_end is not None:
        valid = ok_all & jnp.isfinite(t) & (t <= jnp.min(tx_end))
    else:
        valid = ok_all & (t <= jnp.min(tr[:, -1]))
    return t, valid, k_star


def send_order_ids(tx) -> jnp.ndarray:
    """Exact send-order symbol ids: the id the master's symbol counter
    hands each (helper, round) send at its send instant — the rank of
    ``tx`` over the whole trace.  Causal in real time (the count of
    earlier sends is known at every send instant) even though no forward
    round-major scan can compute it, which is why the *in-scan* decoder
    state uses the per-round approximation (``engine._send_time_ids``)
    and this exact assignment lives in finalize.

    Ties rank round-major (round, then helper index), so a homogeneous
    lockstep trace reproduces the legacy round-robin grid ``g = i*N + n``
    bit for bit.  Unsent slots (tx = +inf) rank after every real send and
    their ids are never absorbed (their ``tr`` is +inf too)."""
    n, m = tx.shape
    flat = jnp.where(jnp.isfinite(tx), tx, jnp.inf).T.ravel()  # round-major
    order = jnp.argsort(flat, stable=True)
    rank = jnp.argsort(order)
    return rank.reshape(m, n).T.astype(jnp.int32)


def finalize_decode(outs: dict, aux: dict, R: int, tx_end) -> Tuple:
    """The shared ``Policy.finalize`` body of the decoder-in-the-loop
    policies: time-exact decode-success completion from the stream trace
    (k_star stays internal; the measured overhead is ``r_n.sum() - R``).
    Symbol identities are the master's send counter
    (:func:`send_order_ids` over the recorded ``tx`` trace); legacy
    traces without a ``tx`` record fall back to the round-robin slots."""
    ids = send_order_ids(outs["tx"]) if "tx" in outs else None
    t, valid, _k_star = decode_completion(
        outs["tr"], aux["decoder"]["tables"], R, tx_end=tx_end, ids=ids)
    return t, valid


def offline_overhead_samples(
    R: int,
    code: fountain.LTCode,
    p_loss: float,
    trials: int = 32,
    seed: int = 0,
) -> np.ndarray:
    """Offline Monte-Carlo of the arrivals-to-decode overhead (host-side).

    Mimics the engine's stream: coded ids go out in slot order, each is
    erased i.i.d. with ``p_loss``, and the survivors are absorbed in order
    until the peeling closure covers all R sources.  Returns the per-trial
    ``k_star - R`` samples (``-1`` when the whole pool cannot decode) — the
    reference distribution the in-engine ``rateless_ccp`` measurement is
    validated against (and the empirical face of the robust-soliton
    overhead bound that :func:`fountain.decode_failure_prob` quantifies).
    """
    rng = np.random.default_rng(seed)
    n_rows = code.n_coded
    out = np.empty(trials, dtype=np.int64)
    for t in range(trials):
        kept = np.flatnonzero(rng.random(n_rows) >= p_loss)
        lo, hi, ans = R, kept.size, -1
        if kept.size >= R and fountain.peel_decode_plan(code, kept) is not None:
            while lo <= hi:
                mid = (lo + hi) // 2
                if fountain.peel_decode_plan(code, kept[:mid]) is not None:
                    ans, hi = mid, mid - 1
                else:
                    lo = mid + 1
        out[t] = ans - R if ans >= 0 else -1
    return out
