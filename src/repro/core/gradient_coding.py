"""Coded gradient aggregation: R-of-(R+K) straggler-tolerant data parallelism.

The paper's fountain-coded sub-tasks, applied to DP training: the "task" is
the gradient sum over R microbatch shards; each worker returns its own shard
gradient (systematic block) and a subset of workers *additionally* compute a
parity — the gradient of a sparse sum of neighbour microbatches (extra
forward/backward = the coding redundancy, exactly the paper's K overhead).
The optimizer step needs any decodable R-subset of the R+K results, so up to
``s`` stragglers/failures per step cost nothing.

Static-XLA adaptation (DESIGN.md §2): XLA cannot drop workers mid-step, so
the survivor set is chosen *before* dispatch (from CCP heartbeat telemetry)
and realized as per-worker decode weights in a weighted ``psum`` — the same
compiled program serves every survivor pattern because the weights are a
(tiny) input, not a constant.

``decode_weights`` solves  w @ G_rx = 1_R : a combination of the received
coded rows equal to the all-ones row recovers the *sum* of all R source
gradients (we never need the individual blocks — cheaper than full decode).
"""

from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .fountain import LTCode, make_lt_code

PyTree = jax.Array  # loose alias for docs


def make_gradient_code(n_workers: int, n_parity: int, seed: int = 0,
                       d_max: Optional[int] = None) -> LTCode:
    """LT code over R=n_workers microbatch-gradient blocks with K parities.

    Parity degrees are capped (default 4) — a parity's degree is the number
    of *extra* microbatch gradients some worker must compute, i.e. compute
    redundancy, so small degrees matter more than soliton fidelity here.
    """
    return make_lt_code(
        R=n_workers, K=n_parity, seed=seed,
        d_max=d_max if d_max is not None else 4,
        coverage_min=2 if n_parity >= 2 else n_parity,
    )


def parity_assignments(code: LTCode) -> list:
    """parity k -> tuple of source worker ids whose microbatches it re-runs.

    Parity k is assigned to worker k % R (round-robin), so redundancy spreads
    evenly; worker w computes parities {k : k % R == w}.
    """
    out = []
    for k in range(code.K):
        row = code.R + k
        nbrs = code.idx[row][code.mask[row]]
        out.append(tuple(int(x) for x in nbrs))
    return out


def decode_weights(code: LTCode, survivors: Sequence[int]) -> np.ndarray:
    """Solve for w with  w @ G[survivors] = 1_R  (gradient-sum recovery).

    survivors: indices into the coded space (0..R+K-1) that returned.
    Returns w (len(survivors),); raises ValueError if the pattern is
    undecodable (caller falls back to waiting / elastic restart).
    """
    G = code.dense_generator()[np.asarray(survivors)]
    ones = np.ones(code.R)
    w, res, rank, _ = np.linalg.lstsq(G.T, ones, rcond=None)
    if not np.allclose(G.T @ w, ones, atol=1e-6):
        raise ValueError(
            f"survivor set {list(survivors)} cannot recover the gradient sum"
        )
    return w.astype(np.float32)


def weight_table(code: LTCode, max_stragglers: int, seed: int = 0,
                 n_patterns: int = 64) -> Tuple[np.ndarray, np.ndarray]:
    """Precompute decode weights for sampled straggler patterns.

    Returns (patterns (P, R+K) bool of survivors, weights (P, R+K) with
    zeros at non-survivors).  Pattern 0 is the no-straggler case (weights =
    systematic ones, parities zero — the fast path costs nothing).
    """
    rng = np.random.default_rng(seed)
    n = code.R + code.K
    pats, ws = [], []
    full = np.ones(n, bool)
    w0 = np.zeros(n, np.float32)
    w0[: code.R] = 1.0
    pats.append(full)
    ws.append(w0)
    tries = 0
    while len(pats) < n_patterns and tries < n_patterns * 20:
        tries += 1
        s = rng.integers(1, max_stragglers + 1)
        lost = rng.choice(n, size=s, replace=False)
        surv = np.setdiff1d(np.arange(n), lost)
        try:
            w = decode_weights(code, surv)
        except ValueError:
            continue
        pat = np.zeros(n, bool)
        pat[surv] = True
        wfull = np.zeros(n, np.float32)
        wfull[surv] = w
        pats.append(pat)
        ws.append(wfull)
    return np.stack(pats), np.stack(ws)


def coded_grad_sum(
    grads: jax.Array,      # (R, ...) systematic per-worker gradients
    parities: jax.Array,   # (K, ...) parity gradients
    weights: jax.Array,    # (R+K,) decode weights (0 at non-survivors)
) -> jax.Array:
    """sum_n g_n from any decodable weighted subset (vectorized test path)."""
    coded = jnp.concatenate([grads, parities], axis=0)
    w = weights.reshape((-1,) + (1,) * (coded.ndim - 1)).astype(coded.dtype)
    return (coded * w).sum(axis=0)


def expected_redundancy(code: LTCode) -> float:
    """Extra compute fraction: sum of parity degrees / R (the paper's K
    overhead translated to FLOPs)."""
    degs = code.degrees()[code.R:]
    return float(degs.sum()) / code.R
