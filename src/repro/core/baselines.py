"""Baselines from §6: Uncoded (two allocations) and HCMM [Reisizadeh et al.].

Uncoded: ``r_n`` *uncoded* packets are pre-assigned to helper ``n`` (summing
to exactly R — no coding, so *every* helper must finish).  Two allocation
rules from the paper: proportional to 1/E[beta_n] ('mean') and proportional
to mu_n ('mu').

HCMM (arXiv:1701.05973): each helper gets a fixed block of MDS-coded rows,
sized by the asymptotically-optimal load. The collector finishes when the
loads of *fully finished* helpers sum to >= R.  Load solver: helper n's
per-time expected useful rate is rho(lmbda) = lmbda * (1 - e^{mu a - mu/lmbda});
the optimum lmbda* solves  ln(1 + u + mu*a) = u  with  u = mu/lmbda - mu*a,
then tau* = R / sum_n rho_n(lmbda_n*)  and  ell_n = lmbda_n* tau*.

Both baselines share the CCP simulator's link/compute timing model so the
comparison is apples-to-apples.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from . import theory
from .simulator import ScenarioConfig, draw_helpers, draw_packet_tables

__all__ = ["uncoded_allocation", "hcmm_loads", "run_uncoded", "run_hcmm"]


# ---------------------------------------------------------------------------
# Allocations
# ---------------------------------------------------------------------------

def uncoded_allocation(R: int, mu, a, rule: str) -> np.ndarray:
    """Integer loads summing to R; rule in {'mean', 'mu'}."""
    mu = np.asarray(mu, dtype=np.float64)
    a = np.asarray(a, dtype=np.float64)
    if rule == "mean":
        w = 1.0 / theory.shifted_exp_mean(a, mu)
    elif rule == "mu":
        w = mu.copy()
    else:
        raise ValueError(f"unknown rule {rule!r}")
    loads = R * w / w.sum()
    return theory.largest_remainder_round(loads, R)


def _hcmm_u_star(mu_a: float) -> float:
    """Solve ln(1 + u + mu*a) = u for u > 0 (Newton; unique positive root)."""
    u = max(mu_a, 1.0)
    for _ in range(100):
        f = np.log1p(u + mu_a) - u
        fp = 1.0 / (1.0 + u + mu_a) - 1.0
        step = f / fp
        u_new = u - step
        if u_new <= 0:
            u_new = u / 2.0
        if abs(u_new - u) < 1e-12:
            u = u_new
            break
        u = u_new
    return float(u)


def hcmm_loads(R: int, mu, a) -> np.ndarray:
    """HCMM asymptotically-optimal per-helper loads (integers)."""
    mu = np.asarray(mu, dtype=np.float64)
    a = np.asarray(a, dtype=np.float64)
    lam = np.empty_like(mu)
    rho = np.empty_like(mu)
    for n in range(mu.shape[0]):
        u = _hcmm_u_star(mu[n] * a[n])
        lam[n] = mu[n] / (u + mu[n] * a[n])
        rho[n] = lam[n] * (1.0 - np.exp(-u))
    tau = R / rho.sum()
    loads = lam * tau
    total = int(np.ceil(loads.sum()))
    return theory.largest_remainder_round(loads, total)


# ---------------------------------------------------------------------------
# Simulation of block-assigned baselines
# ---------------------------------------------------------------------------

def _block_finish_times(cfg: ScenarioConfig, key, R: int, loads: np.ndarray,
                        mu, a, rate, M_override: int | None = None
                        ) -> np.ndarray:
    """Finish time (last computed result at collector) per helper for a fixed
    pre-assigned block of ``loads[n]`` packets, streaming back-to-back sends.

    ``M_override`` draws the packet tables at a fixed horizon (>= max load)
    so results are comparable draw-for-draw with the policy engine's shared
    horizon (tests pin the in-scan block policies against this path)."""
    M = M_override if M_override is not None else int(loads.max())
    if M == 0:
        return np.zeros(cfg.N)
    beta, d_up, d_ack, d_down = draw_packet_tables(key, cfg, mu, a, rate, M, R)
    # Uplink serialized: packet i arrives at cumsum(d_up)[i].
    arrive = jnp.cumsum(d_up, axis=1)

    def step(done_prev, x):
        done = jnp.maximum(x[0], done_prev) + x[1]
        return done, done

    _, done = jax.lax.scan(
        step, jnp.zeros(cfg.N), (arrive.T, beta.T)
    )
    done = done.T  # (N, M)
    tr = done + d_down
    loads_j = jnp.asarray(loads)
    idx = jnp.clip(loads_j - 1, 0, M - 1)
    t_n = jnp.take_along_axis(tr, idx[:, None], axis=1)[:, 0]
    return np.asarray(jnp.where(loads_j > 0, t_n, 0.0))


def run_uncoded(key, cfg: ScenarioConfig, R: int, rule: str = "mean",
                M_override: int | None = None) -> Dict:
    """Uncoded baseline: every helper must finish its block; T = max_n.

    Sequential NumPy reference path; the vmapped/sharded equivalent is
    ``engine.Engine().run(cfg, "uncoded_mean"|"uncoded_mu", keys, R)``.
    """
    k_h, k_p = jax.random.split(key)
    mu, a, rate = draw_helpers(k_h, cfg)
    loads = uncoded_allocation(R, mu, a, rule)
    t_n = _block_finish_times(cfg, k_p, R, loads, mu, a, rate, M_override)
    return dict(T=float(np.max(t_n)), loads=loads, mu=np.asarray(mu), a=np.asarray(a))


def run_hcmm(key, cfg: ScenarioConfig, R: int,
             M_override: int | None = None) -> Dict:
    """HCMM: completion when finished helpers' loads sum to >= R.

    Sequential NumPy reference path; the vmapped/sharded equivalent is
    ``engine.Engine().run(cfg, "hcmm", keys, R)``.
    """
    k_h, k_p = jax.random.split(key)
    mu, a, rate = draw_helpers(k_h, cfg)
    loads = hcmm_loads(R, np.asarray(mu), np.asarray(a))
    t_n = _block_finish_times(cfg, k_p, R, loads, mu, a, rate, M_override)
    order = np.argsort(t_n)
    agg = np.cumsum(loads[order])
    pos = int(np.searchsorted(agg, R))
    if pos >= cfg.N:  # insufficient aggregate redundancy (shouldn't happen)
        pos = cfg.N - 1
    return dict(
        T=float(t_n[order][pos]), loads=loads,
        mu=np.asarray(mu), a=np.asarray(a),
    )
