"""TFRC (RFC 5348) pacing primitives for the ``tfrc_ccp`` policy.

The TFRC throughput equation bounds the allowed sending rate by what a
conformant TCP flow would achieve at loss-event rate ``p`` and RTT ``R``:

    X = s / (R*sqrt(2bp/3) + t_RTO * (3*sqrt(3bp/8)) * p * (1 + 32 p^2))

With the RFC-recommended simplifications ``b = 1`` and ``t_RTO = 4R`` the
packet size ``s`` cancels from the *send interval* (s / X):

    interval(p, R) = R * (sqrt(2p/3) + 12 * sqrt(3p/8) * p * (1 + 32 p^2))

which is what :func:`tfrc_send_interval` computes — ``0`` at ``p = 0``
(no throttle; the policy's CCP pacing rules), growing like ``R*sqrt(p)``
for small ``p`` and like ``R*p^3`` once timeouts dominate.

The loss-EVENT rate estimator is TFRC's key difference from a raw loss
fraction: losses within one RTT of the first loss of an event count as
ONE congestion signal (a radio fade or a drop-tail burst is a single
event however many packets it ate).  :func:`loss_event_update` maintains
a scan-carried EWMA of the per-packet new-event indicator — decayed on
every delivered packet, bumped only when a loss starts a *new* event —
an O(1)-state stand-in for the RFC's eight-interval weighted average
that keeps the estimator vmap/scan-safe.
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["loss_event_update", "tfrc_send_interval"]


def tfrc_send_interval(p, rtt):
    """Minimum allowed send interval at loss-event rate ``p`` and RTT
    estimate ``rtt`` (elementwise, (N,) arrays): the inverse of the RFC
    5348 throughput equation with b=1 and t_RTO=4*RTT (see module doc)."""
    p = jnp.clip(p, 0.0, 1.0)
    return rtt * (jnp.sqrt(2.0 * p / 3.0)
                  + 12.0 * jnp.sqrt(3.0 * p / 8.0) * p * (1.0 + 32.0 * p * p))


def loss_event_update(p_ev, ev_start, lost, received, tx, rtt, *, w):
    """One scan step of the loss-event-rate estimator.

    p_ev:     (N,) current loss-event-rate EWMA.
    ev_start: (N,) send instant of the first loss of the current event
              (-inf before any loss).
    lost:     (N,) bool — this packet was lost.
    received: (N,) bool — this packet was delivered.
    tx:       (N,) this packet's send instant.
    rtt:      (N,) RTT estimate: losses within ``rtt`` of ``ev_start``
              collapse into the ongoing event.
    w:        EWMA weight.

    Returns ``(p_ev, ev_start)``.  A delivered packet decays the rate; a
    loss that starts a new event bumps it; a loss inside the ongoing
    event window — and a never-sent slot — is neutral (already counted /
    not a sample).
    """
    new_event = lost & (tx > ev_start + rtt)
    p_next = jnp.where(
        new_event, w + (1.0 - w) * p_ev,
        jnp.where(received, (1.0 - w) * p_ev, p_ev))
    return p_next, jnp.where(new_event, tx, ev_start)
