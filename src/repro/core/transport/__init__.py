"""Transport layer: delayed ACK/NACK feedback, RTT processes, TFRC pacing.

The engine's scans idealize the control plane: every ``StepCtx``
observation (``on_computed`` receipts, ``decoded_count``, ``queue_delay``)
reaches the pacing controller the instant the underlying event happens.
This package models the feedback channel between the data collector and
the controller as a real link: per-helper RTT processes
(:mod:`.rtt` — fixed / lognormal-jittered / cellular-spike regimes), ACK
loss composed with the existing Gilbert–Elliott burst chain with a
NACK-style retransmission round (:mod:`.delay`), and the TFRC (RFC 5348)
throughput-equation pacing used by the ``tfrc_ccp`` policy
(:mod:`.tfrc`).

The contract (docs/transport.md): the transport delay line shifts
*observations only*.  Ground-truth physics — result arrival times
``outs["tr"]``, helper idle, completion extraction, decode success — stay
time-exact; what moves is when the policy hooks *learn* about them
(``ctx.tr_ok``/``ctx.rtt_ack``/``ctx.tr_prev`` become observed instants,
and ``decode_t_done`` becomes a master-*observed* bound).  With
``rtt_mean = 0`` the observed and physical instants coincide bit-for-bit,
so the transport-enabled scan is bitwise the idealized engine.
"""

from .delay import observation_delay
from .rtt import RTT_DISTS, draw_rtt_tables
from .tfrc import loss_event_update, tfrc_send_interval

__all__ = [
    "RTT_DISTS",
    "draw_rtt_tables",
    "loss_event_update",
    "observation_delay",
    "tfrc_send_interval",
]
