"""The feedback delay line: when does the controller *observe* an event?

A result that physically reaches the collector at ``tr`` is observed by
the pacing controller at ``tr + observation_delay``: one feedback RTT,
doubled when the ACK itself is lost — the controller times out and the
collector answers the NACK-style retransmission request one further RTT
later (the retransmitted ACK is assumed delivered; chaining more rounds
changes the tail, not the model, and is noted in docs/transport.md).

ACK loss composes with the data plane's loss processes: the feedback
share of the channel fades with the same Gilbert–Elliott chain state that
governs data loss at this step (the step-aligned idealization — the ACK
of packet i rides the step-i chain state, mirroring how the decoder
absorbs step-aligned arrivals), plus the i.i.d. ``drop_prob`` floor:

    p_ack = p_drop + l_state - p_drop * l_state      (union of the two)

Everything is shaped so the fleet can broadcast: ``rtt_fb``/``ack_u`` may
carry a leading tenant axis (T, N) while the chain state stays (N,).
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["observation_delay"]


def observation_delay(rtt_fb, ack_u, p_drop, ge_bad=None, ge_params=None):
    """Observation lag of this step's feedback, elementwise over helpers.

    rtt_fb:    feedback RTT samples — (N,) or (T, N).
    ack_u:     ACK-loss uniforms, same shape as ``rtt_fb``.
    p_drop:    scalar i.i.d. loss floor (ChurnConfig.drop_prob).
    ge_bad:    (N,) bool Gilbert–Elliott state at this step, or None.
    ge_params: (4,) shared or (4, N) per-helper GE parameters, or None.

    Returns the delay to add to every observed instant: ``rtt_fb`` on a
    clean ACK, ``2 * rtt_fb`` when the ACK was lost and NACK-retransmitted.
    With ``rtt_fb == 0`` the result is exactly ``0.0`` — the bit-for-bit
    RTT=0 guarantee rests on ``x + 0.0 == x`` for the engine's
    non-negative times.
    """
    p_ack = p_drop
    if ge_bad is not None:
        l_state = jnp.where(ge_bad, ge_params[3], ge_params[2])
        p_ack = p_ack + l_state - p_ack * l_state
    ack_lost = ack_u < p_ack
    return rtt_fb * (1.0 + ack_lost)
