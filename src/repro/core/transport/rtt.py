"""Per-helper feedback-RTT processes (ChurnConfig ``rtt_*`` knobs).

The feedback RTT of helper n at packet i factors as ``rtt_base[n] *
rtt_jit[n, i]``: a static per-helper base (heterogeneous control paths —
``rtt_het`` spreads helpers uniformly in ``rtt_mean * [1 - het, 1 + het]``)
times a unit-mean per-packet jitter drawn by regime:

  'fixed'      — no jitter (deterministic control path).
  'lognormal'  — log-normal, mean 1, log-std ``rtt_sigma`` (WAN queueing
                 jitter, cf. the wireless setting of arXiv:2004.14170).
  'cell'       — occasional cellular latency spikes: with prob
                 ``rtt_spike_prob`` the sample is ``rtt_spike_scale`` x
                 the base (RRC state promotions / bufferbloat events),
                 else 1.

The factorization is what lets the fleet share the per-helper base across
tenants (a helper's control path is a helper property, like ``mu``) while
each tenant draws independent per-packet jitter — task 0 of a fleet then
multiplies exactly the single-task operands, preserving the equivalence
spine.  All draws come from a key folded off the main dynamics key, so
enabling transport never perturbs the existing churn tables.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["RTT_DISTS", "draw_rtt_tables"]

#: 'off' disables the transport path entirely (the structural knob the
#: engine scans specialize on via ``ChurnConfig.static_key()``).
RTT_DISTS = ("off", "fixed", "lognormal", "cell")


def draw_rtt_tables(key, ch, N: int, M: int) -> dict:
    """Transport tables for one rep: ``rtt_base`` (N,) per-helper base RTT,
    ``rtt_jit`` (N, M) unit-mean per-packet jitter, and ``ack_u`` (N, M)
    uniforms for the ACK-loss draw (:func:`repro.core.transport.delay.
    observation_delay`).  ``ch`` is the :class:`~repro.core.simulator.
    ChurnConfig` carrying the ``rtt_*`` knobs."""
    kb, kj, ka = jax.random.split(key, 3)
    het = ch.rtt_het
    base = ch.rtt_mean * (
        1.0 + het * (2.0 * jax.random.uniform(kb, (N,)) - 1.0))
    if ch.rtt_dist == "lognormal":
        # exp(sigma z - sigma^2/2): unit mean, log-std rtt_sigma.
        mu_log = -0.5 * ch.rtt_sigma ** 2
        z = jax.random.normal(kj, (N, M))
        jit = jnp.exp(mu_log + ch.rtt_sigma * z)
    elif ch.rtt_dist == "cell":
        spike = jax.random.bernoulli(kj, ch.rtt_spike_prob, (N, M))
        jit = jnp.where(spike, np.float32(ch.rtt_spike_scale), 1.0)
    else:  # 'fixed'
        jit = jnp.ones((N, M))
    return dict(rtt_base=base, rtt_jit=jit, ack_u=jax.random.uniform(ka, (N, M)))
