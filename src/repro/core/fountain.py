"""Rateless (LT / fountain) coding over row-blocks.

The paper packetizes rows of ``A`` and codes them with Fountain codes
(LT/Raptor) so that *any* ``R`` of the ``R+K`` coded packets complete the
task.  On TPU a "packet" becomes an MXU-aligned *row-block* and GF(2) XOR
becomes real-valued addition (coefficients are +1), which preserves the
peeling decoder exactly (subtraction replaces XOR-cancellation).

We use a *systematic* construction: coded packets ``0..R-1`` are the source
blocks themselves (degree-1), packets ``R..R+K-1`` are parity blocks whose
degrees follow the robust-soliton distribution.  Systematic rateless codes
have zero decode cost on the no-straggler fast path and O(R) peeling decode
otherwise — matching the paper's O(R) Raptor complexity argument (§2).

Degree neighbours are represented densely as ``(n_coded, d_max)`` index +
mask arrays so that encoding is a gather + masked-sum, which maps 1:1 onto
the Pallas ``lt_encode`` / ``coded_matmul`` kernels.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "ideal_soliton",
    "robust_soliton",
    "LTCode",
    "make_lt_code",
    "encode",
    "encode_ref",
    "DecodePlan",
    "PlanRound",
    "peel_decode_plan",
    "plan_rounds",
    "apply_decode_plan",
    "decode",
    "decode_failure_prob",
]


# ---------------------------------------------------------------------------
# Degree distributions
# ---------------------------------------------------------------------------

def ideal_soliton(R: int) -> np.ndarray:
    """Ideal soliton distribution rho(d), d = 1..R. Returns probs shape (R,)."""
    if R < 1:
        raise ValueError(f"R must be >= 1, got {R}")
    p = np.zeros(R, dtype=np.float64)
    p[0] = 1.0 / R
    d = np.arange(2, R + 1, dtype=np.float64)
    p[1:] = 1.0 / (d * (d - 1.0))
    return p


def robust_soliton(R: int, c: float = 0.03, delta: float = 0.5) -> np.ndarray:
    """Robust soliton distribution mu(d) (Luby'02), d = 1..R."""
    rho = ideal_soliton(R)
    S = c * np.log(R / delta) * np.sqrt(R) if R > 1 else 1.0
    S = max(S, 1.0)
    tau = np.zeros(R, dtype=np.float64)
    pivot = int(np.floor(R / S))
    pivot = min(max(pivot, 1), R)
    d = np.arange(1, R + 1, dtype=np.float64)
    head = d < pivot
    tau[head] = S / (R * d[head])
    tau[pivot - 1] = S * np.log(S / delta) / R if pivot >= 1 else 0.0
    mu = rho + tau
    mu = np.clip(mu, 0.0, None)
    return mu / mu.sum()


# ---------------------------------------------------------------------------
# Code construction
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LTCode:
    """A (systematic) LT code over ``R`` source blocks with ``K`` parities.

    idx:  (R+K, d_max) int32   — source-block neighbours of each coded block.
    mask: (R+K, d_max) bool    — validity of each neighbour slot.
    coef: (R+K, d_max) float32 — combination coefficients (systematic rows
          1.0; parity rows Rademacher ±1).  GF(2) XOR maps to real addition,
          and upgrading the all-ones combinations to random signs costs
          nothing on TPU (add vs. subtract) while making small-block loss
          patterns generically full-rank over the reals (the 0/1 version
          loses rank whenever two loss-set restrictions sum identically).
    R, K: ints.
    """

    idx: np.ndarray
    mask: np.ndarray
    coef: np.ndarray
    R: int
    K: int

    @property
    def n_coded(self) -> int:
        return self.R + self.K

    @property
    def d_max(self) -> int:
        return int(self.idx.shape[1])

    @property
    def weights(self) -> np.ndarray:
        """(R+K, d_max) float32 = mask * coef — the kernel/encode operand."""
        return (self.mask * self.coef).astype(np.float32)

    def degrees(self) -> np.ndarray:
        return self.mask.sum(axis=1).astype(np.int32)

    def dense_generator(self) -> np.ndarray:
        """(R+K, R) generator matrix (float32). For tests/small R only."""
        G = np.zeros((self.n_coded, self.R), dtype=np.float32)
        rows = np.repeat(np.arange(self.n_coded), self.d_max)
        cols = self.idx.reshape(-1)
        valid = self.mask.reshape(-1)
        vals = self.coef.reshape(-1)
        np.add.at(G, (rows[valid], cols[valid]), vals[valid])
        # repeated neighbour indices would double-count; construction avoids
        # them (sampling w/o replacement).
        return G


def make_lt_code(
    R: int,
    K: int,
    seed: int = 0,
    c: float = 0.03,
    delta: float = 0.5,
    d_max: Optional[int] = None,
    systematic: bool = True,
    coverage_min: int = 2,
    parity_degree: Optional[int] = None,
) -> LTCode:
    """Build a (systematic) LT code: R source (identity) + K parity blocks.

    ``parity_degree``: fixed degree for every parity instead of soliton
    sampling.  Dense parities (~R/2) make small-block erasure patterns
    generically full-rank (random ±1 matrix behaviour) at higher encode
    cost — used by placement-validated plans where encode adds are cheap
    relative to the fused matmul (core/coded_matmul.py); soliton stays the
    default for the paper-faithful O(R) codec.

    ``coverage_min`` (Raptor-style outer-code simplification): soliton
    coverage guarantees are asymptotic in R; for the small block counts used
    on a TPU mesh (tens of row-blocks), a source block covered by zero or one
    parity is a single point of failure (losing its systematic copy — or the
    copy plus its lone parity — is unrecoverable).  Every source is therefore
    appended round-robin to parity rows until it appears in at least
    ``coverage_min`` of them (capped at K).  Set 0 to disable (pure soliton).
    """
    if R < 1 or K < 0:
        raise ValueError(f"need R>=1, K>=0; got R={R} K={K}")
    rng = np.random.default_rng(seed)
    if parity_degree is not None:
        degs = np.full(K, min(max(parity_degree, 1), R), dtype=np.int64)
    else:
        probs = robust_soliton(R, c=c, delta=delta)
        # Parity degrees: resample degree-1 parities to >=2 when possible —
        # a degree-1 parity duplicates a systematic block, wasting overhead.
        degs = rng.choice(np.arange(1, R + 1), size=K, p=probs)
        if R >= 2:
            degs = np.where(degs < 2, 2, degs)
    if d_max is not None:
        degs = np.minimum(degs, d_max)
    nbr_sets = [
        set(rng.choice(R, size=int(degs[k]), replace=False).tolist())
        for k in range(K)
    ]
    if coverage_min > 0 and K > 0:
        want = min(coverage_min, K)
        counts = np.zeros(R, dtype=np.int64)
        for s in nbr_sets:
            for src in s:
                counts[src] += 1
        rr = list(rng.permutation(K))
        ptr = 0
        for src in np.flatnonzero(counts < want):
            while counts[src] < want:
                for _ in range(K):
                    tgt = int(rr[ptr % K])
                    ptr += 1
                    if src not in nbr_sets[tgt]:
                        nbr_sets[tgt].add(int(src))
                        counts[src] += 1
                        break
                else:
                    break  # source already in every parity
    nbr_sets = [sorted(s) for s in nbr_sets]
    eff_dmax = max((len(s) for s in nbr_sets), default=1)
    eff_dmax = max(eff_dmax, 1)
    if d_max is not None:
        eff_dmax = max(min(eff_dmax, max(d_max, 1)), 1)
        # Coverage-aware truncation: when trimming a parity to d_max, drop
        # its *most-covered* members first so no source silently loses its
        # only parity slot.
        counts = np.zeros(R, dtype=np.int64)
        for s in nbr_sets:
            for src in s:
                counts[src] += 1
        trimmed = []
        for s in nbr_sets:
            while len(s) > eff_dmax:
                drop = max(s, key=lambda src: (counts[src], src))
                s = [x for x in s if x != drop]
                counts[drop] -= 1
            trimmed.append(sorted(s))
        nbr_sets = trimmed
        # Repair pass: truncation may still zero a source's coverage when the
        # slot budget K*d_max is tight — swap it in over a member that is
        # covered elsewhere (count >= 2).
        for src in np.flatnonzero(counts == 0):
            done = False
            for s in nbr_sets:
                if done:
                    break
                for victim in sorted(s, key=lambda v: -counts[v]):
                    if counts[victim] >= 2 and src not in s:
                        s.remove(victim)
                        s.append(int(src))
                        s.sort()
                        counts[victim] -= 1
                        counts[src] += 1
                        done = True
                        break
        nbr_sets = [sorted(s) for s in nbr_sets]
    n_coded = R + K if systematic else K
    idx = np.zeros((n_coded, eff_dmax), dtype=np.int32)
    mask = np.zeros((n_coded, eff_dmax), dtype=bool)
    coef = np.zeros((n_coded, eff_dmax), dtype=np.float32)
    row = 0
    if systematic:
        idx[:R, 0] = np.arange(R, dtype=np.int32)
        mask[:R, 0] = True
        coef[:R, 0] = 1.0
        row = R
    for k in range(K):
        d = len(nbr_sets[k])
        idx[row + k, :d] = np.asarray(nbr_sets[k], dtype=np.int32)
        mask[row + k, :d] = True
        coef[row + k, :d] = rng.choice(np.array([-1.0, 1.0], np.float32), size=d)
    return LTCode(idx=idx, mask=mask, coef=coef, R=R, K=K)


# ---------------------------------------------------------------------------
# Encoding
# ---------------------------------------------------------------------------

def encode_ref(blocks: jnp.ndarray, idx: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Pure-jnp oracle: coded[b] = sum_j mask[b,j] * blocks[idx[b,j]].

    blocks: (R, *rest); idx/mask: (n_coded, d_max). Returns (n_coded, *rest).
    """
    gathered = jnp.take(blocks, idx, axis=0)  # (n_coded, d_max, *rest)
    m = mask.astype(blocks.dtype)
    m = m.reshape(m.shape + (1,) * (gathered.ndim - m.ndim))
    return (gathered * m).sum(axis=1)


def encode(blocks: jnp.ndarray, code: LTCode) -> jnp.ndarray:
    """Encode source blocks (R, *rest) -> coded blocks (R+K, *rest)."""
    if blocks.shape[0] != code.R:
        raise ValueError(f"blocks.shape[0]={blocks.shape[0]} != R={code.R}")
    return encode_ref(blocks, jnp.asarray(code.idx), jnp.asarray(code.weights))


# ---------------------------------------------------------------------------
# Decoding: symbolic peeling plan (host) + jnp application (device)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DecodePlan:
    """Schedule produced by peeling. Applying it reconstructs all R sources.

    direct_src / direct_coded / direct_coef: sources recovered from received
        degree-1 blocks (systematic fast path), aligned 1:1; the value is
        coded/coef.
    order_coded: (T,) coded-block position (into the *received* array) used at
        step t.
    order_src:   (T,) source index recovered at step t.
    order_pivot: (T,) coefficient of the recovered source in that block.
    order_nbr_idx / order_nbr_coef: (T, d_max) other neighbours of that coded
        block (all recovered before step t) and their coefficients to
        subtract (coef 0 = padding).
    """

    direct_src: np.ndarray
    direct_coded: np.ndarray
    direct_coef: np.ndarray
    order_coded: np.ndarray
    order_src: np.ndarray
    order_pivot: np.ndarray
    order_nbr_idx: np.ndarray
    order_nbr_coef: np.ndarray
    R: int

    @property
    def n_peeled(self) -> int:
        return int(self.order_src.shape[0])


def peel_decode_plan(
    code: LTCode, received_ids: np.ndarray
) -> Optional[DecodePlan]:
    """Run symbolic peeling over the received coded blocks.

    received_ids: indices into the coded space (0..R+K-1) of blocks that
    arrived. Returns a DecodePlan, or None if peeling stalls before
    recovering all R sources (caller may retry with more blocks or use the
    dense fallback in :func:`decode`).
    """
    received_ids = np.asarray(received_ids, dtype=np.int64)
    R, d_max = code.R, code.d_max
    n_rx = received_ids.shape[0]
    # Neighbour sets of received blocks (as growing/shrinking residual graph)
    # plus per-(block, source) coefficients.
    nbrs = [set(code.idx[b, code.mask[b]].tolist()) for b in received_ids]
    coef_of = [
        {int(s): float(c) for s, c in
         zip(code.idx[b, code.mask[b]], code.coef[b, code.mask[b]])}
        for b in received_ids
    ]
    known = np.zeros(R, dtype=bool)

    direct_src, direct_coded, direct_coef = [], [], []
    order_coded, order_src, order_pivot, order_nbrs = [], [], [], []

    # Fast path: degree-1 received blocks give sources directly.
    ripple = []
    for pos in range(n_rx):
        if len(nbrs[pos]) == 1:
            s = next(iter(nbrs[pos]))
            if not known[s]:
                known[s] = True
                direct_src.append(s)
                direct_coded.append(pos)
                direct_coef.append(coef_of[pos][s])
                ripple.append(s)
            nbrs[pos] = set()

    # Build reverse map: source -> received block positions containing it.
    contains: dict[int, list[int]] = {}
    for pos in range(n_rx):
        for s in nbrs[pos]:
            contains.setdefault(s, []).append(pos)

    residual_deg = np.array([len(x) for x in nbrs], dtype=np.int64)
    # Peel: subtract known sources; blocks reaching residual degree 1 release
    # a new source.
    pending = list(ripple)
    # Also blocks that already have all-but-one neighbour known.
    while True:
        while pending:
            s = pending.pop()
            for pos in contains.get(s, ()):  # blocks containing s
                if s in nbrs[pos]:
                    nbrs[pos].discard(s)
                    residual_deg[pos] -= 1
                    if residual_deg[pos] == 1:
                        t = next(iter(nbrs[pos]))
                        if not known[t]:
                            known[t] = True
                            # other neighbours of this coded block = original
                            # neighbours minus t — all known at this point.
                            all_nb = set(
                                code.idx[received_ids[pos], code.mask[received_ids[pos]]].tolist()
                            )
                            others = sorted(all_nb - {t})
                            order_coded.append(pos)
                            order_src.append(t)
                            order_pivot.append(coef_of[pos][t])
                            order_nbrs.append(
                                [(o, coef_of[pos][o]) for o in others]
                            )
                            pending.append(t)
                        nbrs[pos] = set()
                        residual_deg[pos] = 0
        if known.all():
            break
        # stalled
        return None

    T = len(order_src)
    nbr_idx = np.zeros((T, d_max), dtype=np.int32)
    nbr_coef = np.zeros((T, d_max), dtype=np.float32)
    for t, others in enumerate(order_nbrs):
        for j, (o, c) in enumerate(others):
            nbr_idx[t, j] = o
            nbr_coef[t, j] = c
    return DecodePlan(
        direct_src=np.asarray(direct_src, dtype=np.int32),
        direct_coded=np.asarray(direct_coded, dtype=np.int32),
        direct_coef=np.asarray(direct_coef, dtype=np.float32),
        order_coded=np.asarray(order_coded, dtype=np.int32),
        order_src=np.asarray(order_src, dtype=np.int32),
        order_pivot=np.asarray(order_pivot, dtype=np.float32),
        order_nbr_idx=nbr_idx,
        order_nbr_coef=nbr_coef,
        R=R,
    )


@dataclasses.dataclass(frozen=True)
class PlanRound:
    """One dependency level of a peeling plan (see :func:`plan_rounds`).

    All ``S`` sources of a round depend only on sources recovered in earlier
    rounds (or directly), so the whole round is one batched masked
    gather-subtract — the unit of work of the ``kernels/lt_decode`` Pallas
    kernel.  ``coded``/``src``/``pivot`` are (S,); ``nbr_idx``/``nbr_coef``
    are (S, d_max) with coef 0 = padding.
    """

    coded: np.ndarray
    src: np.ndarray
    pivot: np.ndarray
    nbr_idx: np.ndarray
    nbr_coef: np.ndarray

    @property
    def size(self) -> int:
        return int(self.src.shape[0])


def plan_rounds(plan: DecodePlan) -> list:
    """Levelize a sequential :class:`DecodePlan` into parallel rounds.

    Step ``t`` recovers ``order_src[t]`` by subtracting already-recovered
    neighbours; its *round* is ``1 + max(round of those neighbours)`` with
    directly-received (degree-1) sources at round 0.  Steps inside one round
    are mutually independent, so a round executes as a single batched peel —
    the round count is the decode's critical path, typically O(log R) deep
    versus the O(R) sequential scan of :func:`apply_decode_plan`.
    """
    depth = np.full(plan.R, -1, dtype=np.int64)
    depth[plan.direct_src] = 0
    T = plan.n_peeled
    step_round = np.zeros(T, dtype=np.int64)
    for t in range(T):
        nbrs = plan.order_nbr_idx[t][plan.order_nbr_coef[t] != 0]
        d = 1 + (int(depth[nbrs].max()) if nbrs.size else 0)
        assert nbrs.size == 0 or depth[nbrs].min() >= 0, \
            "plan step depends on an unrecovered source"
        depth[plan.order_src[t]] = d
        step_round[t] = d
    rounds = []
    for d in range(1, int(step_round.max(initial=0)) + 1):
        sel = np.flatnonzero(step_round == d)
        rounds.append(PlanRound(
            coded=plan.order_coded[sel],
            src=plan.order_src[sel],
            pivot=plan.order_pivot[sel],
            nbr_idx=plan.order_nbr_idx[sel],
            nbr_coef=plan.order_nbr_coef[sel],
        ))
    return rounds


def apply_decode_plan(coded_rx: jnp.ndarray, plan: DecodePlan) -> jnp.ndarray:
    """Apply a peeling plan to received coded blocks (n_rx, *rest) -> (R, *rest)."""
    rest = coded_rx.shape[1:]
    src = jnp.zeros((plan.R,) + rest, dtype=coded_rx.dtype)
    if plan.direct_src.size:
        dcoef = jnp.asarray(plan.direct_coef).reshape((-1,) + (1,) * len(rest))
        src = src.at[jnp.asarray(plan.direct_src)].set(
            coded_rx[jnp.asarray(plan.direct_coded)] / dcoef.astype(coded_rx.dtype)
        )
    if plan.order_src.size == 0:
        return src

    order_coded = jnp.asarray(plan.order_coded)
    order_src = jnp.asarray(plan.order_src)
    order_pivot = jnp.asarray(plan.order_pivot)
    nbr_idx = jnp.asarray(plan.order_nbr_idx)
    nbr_coef = jnp.asarray(plan.order_nbr_coef)

    def step(src, t):
        c = coded_rx[order_coded[t]]
        gathered = src[nbr_idx[t]]  # (d_max, *rest)
        w = nbr_coef[t].astype(src.dtype).reshape((-1,) + (1,) * len(rest))
        val = (c - (gathered * w).sum(axis=0)) / order_pivot[t].astype(src.dtype)
        return src.at[order_src[t]].set(val), None

    src, _ = jax.lax.scan(step, src, jnp.arange(plan.order_src.shape[0]))
    return src


def decode(
    coded_rx: jnp.ndarray,
    code: LTCode,
    received_ids: np.ndarray,
) -> Tuple[jnp.ndarray, str]:
    """Decode received coded blocks back to the R source blocks.

    Tries O(R) peeling first; falls back to dense least-squares (Gaussian
    elimination) over the real generator rows — always succeeds when the
    received rows span the source space. Returns (blocks, method).
    """
    plan = peel_decode_plan(code, received_ids)
    if plan is not None:
        return apply_decode_plan(coded_rx, plan), "peel"
    G = code.dense_generator()[np.asarray(received_ids)]  # (n_rx, R)
    if np.linalg.matrix_rank(G) < code.R:
        raise ValueError("received blocks do not span the source space")
    flat = coded_rx.reshape(coded_rx.shape[0], -1)
    sol = jnp.linalg.lstsq(jnp.asarray(G), flat)[0]
    return sol.reshape((code.R,) + coded_rx.shape[1:]).astype(coded_rx.dtype), "dense"


def decode_failure_prob(
    R: int, K: int, n_lost: int, trials: int = 200, seed: int = 0
) -> dict:
    """Monte-Carlo decode-failure statistics when ``n_lost`` coded blocks
    (uniform w/o replacement) are missing. Returns
    ``{'peel_stall': p1, 'unrecoverable': p2}`` — a peel stall falls back to
    the dense O(R^3) solve (still succeeds when the received rows span the
    source space); 'unrecoverable' means even that fails (rank deficiency).
    Used by benchmarks/overhead.py."""
    rng = np.random.default_rng(seed)
    stalls = 0
    unrec = 0
    for t in range(trials):
        code = make_lt_code(R, K, seed=seed * 7919 + t)
        lost = rng.choice(R + K, size=n_lost, replace=False)
        keep = np.setdiff1d(np.arange(R + K), lost)
        if peel_decode_plan(code, keep) is None:
            stalls += 1
            G = code.dense_generator()[keep]
            if np.linalg.matrix_rank(G) < R:
                unrec += 1
    return {"peel_stall": stalls / trials, "unrecoverable": unrec / trials}
