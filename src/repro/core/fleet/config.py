"""FleetConfig: the static shape of a multi-tenant fleet run.

A frozen (hashable) dataclass so a fleet instance can ride along as a
static jit argument of ``engine._fleet_batch_jit`` exactly like the policy
object: everything here is *structural* — tenant count, service
discipline, admission rule, arrival process — and changing any of it is a
retrace, while all per-rep randomness (releases, random placement) flows
through keys.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from .queues import DISCIPLINES

ARRIVALS = ("batch", "poisson", "uniform")

__all__ = ["ARRIVALS", "FleetConfig"]


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    """Multi-tenant fleet shape (see docs/fleet.md).

    n_tasks:    concurrent tenants sharing the helper pool.
    discipline: per-helper service order for same-round jobs — 'fifo'
                (arrival order), 'priority' (non-preemptive, by the
                per-task priority key), or 'ps' (egalitarian processor
                sharing).  See :mod:`repro.core.fleet.queues`.
    placement:  admission rule choosing which helpers each task recruits
                ('all', 'striped', 'random', 'fastest', or a custom rule
                via :func:`repro.core.fleet.register_placement`).
    helpers_per_task: recruit-set size for the non-'all' placements
                (None -> max(N // n_tasks, 1), i.e. a fair partition).
    arrival:    task release process — 'batch' (all at t=0), 'poisson'
                (rate ``load``), or 'uniform' (deterministic 1/``load``
                spacing).  Task 0 always releases at t=0 so a 1-task fleet
                reproduces the single-task engine exactly.
    load:       task arrival rate in tasks/sec (poisson/uniform only).
    priority:   per-task priority keys, smaller = served first ('priority'
                discipline; None -> the task index, i.e. earlier tenants
                win ties).
    """

    n_tasks: int = 1
    discipline: str = "fifo"
    placement: str = "all"
    helpers_per_task: Optional[int] = None
    arrival: str = "batch"
    load: float = 0.0
    priority: Optional[Tuple[float, ...]] = None

    def __post_init__(self):
        if not (isinstance(self.n_tasks, int) and self.n_tasks >= 1):
            raise ValueError(f"n_tasks must be an int >= 1, got {self.n_tasks!r}")
        if self.discipline not in DISCIPLINES:
            raise ValueError(
                f"unknown discipline {self.discipline!r}; known: {DISCIPLINES}"
            )
        if self.arrival not in ARRIVALS:
            raise ValueError(
                f"unknown arrival {self.arrival!r}; known: {ARRIVALS}"
            )
        if self.arrival != "batch" and not self.load > 0:
            raise ValueError(
                f"arrival={self.arrival!r} needs load > 0 (tasks/sec), "
                f"got {self.load!r}"
            )
        if self.helpers_per_task is not None and self.helpers_per_task < 1:
            raise ValueError(
                f"helpers_per_task must be >= 1 or None, got "
                f"{self.helpers_per_task!r}"
            )
        if self.priority is not None:
            p = tuple(float(v) for v in self.priority)
            object.__setattr__(self, "priority", p)
            if len(p) != self.n_tasks:
                raise ValueError(
                    f"priority must have n_tasks={self.n_tasks} entries, "
                    f"got {len(p)}"
                )

    def static_key(self) -> str:
        """The knob the fleet scan trace specializes on (the static
        ``fleet_static`` argument of ``fleet.stream.fleet_stream``)."""
        return self.discipline
