"""Admission control: which helpers each tenant recruits, and when tasks
release.

A placement rule maps ``(key, fleet, cfg, mu, a, rate)`` to a (T, N) bool
recruit mask — task t's stream to helper n exists iff ``recruit[t, n]``
(a non-recruited stream simply never sends: its tx stays +inf, the
engine's standard stopped-stream sentinel).  Rules are registered by name
so experiments can plug in custom admission logic without touching the
engine:

    @fleet.register_placement("my_rule")
    def my_rule(key, fleet, cfg, mu, a, rate):
        return recruit_mask  # (n_tasks, cfg.N) bool

Built-ins: ``all`` (every tenant recruits the whole pool), ``striped``
(contiguous blocks of ``helpers_per_task``, disjoint while they fit —
the controlled way to sweep offered load past the saturation knee),
``random`` (independent uniform recruit sets per tenant), ``fastest``
(every tenant chases the same top helpers by expected service rate
``1/E[beta] = 1/(a + 1/mu)`` — maximal contention on the fast helpers,
the stress case for queue-aware pacing).
"""

from __future__ import annotations

from typing import Callable, Dict

import jax
import jax.numpy as jnp

__all__ = ["PLACEMENTS", "register_placement", "place", "draw_releases"]

PLACEMENTS: Dict[str, Callable] = {}


def register_placement(name: str, fn: Callable = None):
    """Register a placement rule under ``name`` (usable as a decorator)."""
    if fn is None:
        return lambda f: register_placement(name, f)
    PLACEMENTS[name] = fn
    return fn


def _h_eff(fleet, n: int) -> int:
    """Recruit-set size: the configured ``helpers_per_task`` or a fair
    partition of the pool, never below 1 nor above N."""
    h = fleet.helpers_per_task
    if h is None:
        h = max(n // fleet.n_tasks, 1)
    return min(h, n)


@register_placement("all")
def _place_all(key, fleet, cfg, mu, a, rate):
    return jnp.ones((fleet.n_tasks, cfg.N), bool)


@register_placement("striped")
def _place_striped(key, fleet, cfg, mu, a, rate):
    """Task t recruits the h contiguous helpers starting at t*h (mod N):
    disjoint pools while ``n_tasks * h <= N``, wrapping into overlap
    beyond — offered load grows linearly with the tenant count."""
    n = cfg.N
    h = _h_eff(fleet, n)
    t_idx = jnp.arange(fleet.n_tasks)[:, None]
    idx = (t_idx * h + jnp.arange(h)[None, :]) % n
    return jnp.zeros((fleet.n_tasks, n), bool).at[
        jnp.broadcast_to(t_idx, idx.shape), idx].set(True)


@register_placement("random")
def _place_random(key, fleet, cfg, mu, a, rate):
    n = cfg.N
    h = _h_eff(fleet, n)

    def one(k):
        perm = jax.random.permutation(k, n)
        return jnp.zeros((n,), bool).at[perm[:h]].set(True)

    return jax.vmap(one)(jax.random.split(key, fleet.n_tasks))


@register_placement("fastest")
def _place_fastest(key, fleet, cfg, mu, a, rate):
    n = cfg.N
    h = _h_eff(fleet, n)
    w = 1.0 / (a + 1.0 / mu)  # expected service rate 1/E[beta]
    row = jnp.zeros((n,), bool).at[jnp.argsort(-w)[:h]].set(True)
    return jnp.broadcast_to(row[None], (fleet.n_tasks, n))


def place(key, fleet, cfg, mu, a, rate):
    """Resolve the fleet's placement rule and priority keys.

    Returns ``(recruit, prio)``: recruit (T, N) bool, prio (T,) f32 —
    smaller priority is served first under the 'priority' discipline.
    Unknown rules raise with the known list (the fail-loudly contract of
    the policy registry, applied to placements)."""
    if fleet.placement not in PLACEMENTS:
        raise ValueError(
            f"unknown placement {fleet.placement!r}; known: "
            f"{sorted(PLACEMENTS)} (register_placement adds custom rules)"
        )
    recruit = PLACEMENTS[fleet.placement](key, fleet, cfg, mu, a, rate)
    if fleet.priority is not None:
        prio = jnp.asarray(fleet.priority, dtype=jnp.float32)
    else:
        prio = jnp.arange(fleet.n_tasks, dtype=jnp.float32)
    return recruit, prio


def draw_releases(key, fleet):
    """(T,) task release times under ``fleet.arrival``.  Task 0 always
    releases at t=0, so a 1-task fleet reproduces the single-task engine
    exactly; 'uniform' spaces tasks deterministically at 1/load, 'poisson'
    draws exponential inter-arrivals at rate ``load``."""
    T = fleet.n_tasks
    if fleet.arrival == "batch":
        return jnp.zeros(T)
    if fleet.arrival == "uniform":
        return jnp.arange(T) / fleet.load
    gaps = jax.random.exponential(key, (T,)) / fleet.load
    return jnp.concatenate([jnp.zeros(1), jnp.cumsum(gaps[1:])])
