"""Multi-tenant fleet layer: shared helpers, queues, admission, metrics.

The single-task engine (:mod:`repro.core.engine`) gives each task a
dedicated pool of N helpers.  This package models the edge setting where
T tenants *share* the pool: :class:`FleetConfig` describes the fleet
shape, :func:`fleet_stream` runs the event-clock scan that serializes
per-helper busy time across tenants (:mod:`.queues` has the service
disciplines), :mod:`.admission` decides who recruits whom and when tasks
release, and :mod:`.metrics` reduces a fleet trace to utilization /
fairness.  Entry point: :meth:`repro.core.engine.Engine.run_fleet`.
"""

from .queues import DISCIPLINES, serve_round
from .config import ARRIVALS, FleetConfig
from .admission import PLACEMENTS, draw_releases, place, register_placement
from .metrics import helper_utilization, jain_fairness
from .stream import fleet_stream

__all__ = [
    "ARRIVALS",
    "DISCIPLINES",
    "FleetConfig",
    "PLACEMENTS",
    "draw_releases",
    "fleet_stream",
    "helper_utilization",
    "jain_fairness",
    "place",
    "register_placement",
    "serve_round",
]
