"""The event-clock fleet scan: one shared helper pool, many tenants.

``fleet_stream`` generalizes :func:`repro.core.engine.policy_stream` from
"one task, N dedicated helpers" to T tasks contending for the same N
helpers.  The scan step is one *round* of the global virtual clock: every
task contributes the current packet of each of its (task, helper) streams,
the round's arrivals at each helper are serialized by the configured
service discipline against the helper's carried busy time
(:func:`repro.core.fleet.queues.serve_round`), and the policy hooks then
run per task on exactly the step kernels the single-task scan uses
(``engine._churn_step`` / ``_ge_step`` / ``_decode_step`` /
``_hook_step``) — which is why a 1-task fleet is bit-for-bit the
single-task engine (tests/test_fleet.py pins this against the goldens for
every registered policy).

Causality (mirrors the decoder's step-aligned idealization in
docs/policies.md): rounds serialize through the per-helper busy-time
carry, so cross-round ordering is always causally consistent; two jobs
*within* one round are ordered by the discipline alone, not by the global
interleaving of arrivals across rounds.  Under CCP-style pacing — at most
one outstanding packet per stream per helper — the approximation error is
bounded by one in-flight packet per tenant.

Churn under contention: the helper-state lookups (outage, slowdown, GE
loss) must be evaluated *before* same-round peers are serialized — a job's
queue position depends on which peers were lost this round, so evaluating
churn after serialization would be circular.  The reference time is
``t_sta0 = max(arrive, busy)``, the start the job would see on a dedicated
helper; at T=1 that IS the single-task start, so the shortcut costs
nothing where it must cost nothing.

Admission composes with the stopped-stream sentinel: a non-recruited
(task, helper) stream starts at tx = +inf and every registered policy
propagates +inf (``next_load`` of a never-started stream returns +inf), so
no recruit masking is needed inside the step.

The decoder-in-the-loop path runs one independent peeling decoder per
tenant (tasks are separate computations; they share helpers, not
symbols), with per-task send-time symbol ids and the per-task
``decode_t_done`` real-time gate preserved.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .. import ccp as ccp_mod
from .. import engine
from .. import policies as policies_mod
from . import queues

__all__ = ["fleet_stream"]


@functools.partial(
    jax.jit,
    static_argnames=("policy", "cfg_static", "fleet_static", "churn_static",
                     "aux_task_axis"),
)
def fleet_stream(beta, d_up, d_ack, d_down, release, recruit, prio, policy,
                 cfg_static, fleet_static, churn_static=None, dyn=None,
                 a=None, aux=None, aux_task_axis=False):
    """Simulate M rounds of T tenant streams over N shared helpers.

    beta / d_up / d_ack / d_down: (T, N, M) per-tenant packet tables
    (:func:`repro.core.simulator.draw_packet_tables_fleet`); release (T,)
    task release times; recruit (T, N) bool admission mask; prio (T,)
    priority keys (smaller = served first under the 'priority'
    discipline).  ``fleet_static`` is the service discipline
    (``FleetConfig.static_key()``); cfg_static / churn_static / dyn / a /
    aux as in :func:`~repro.core.engine.policy_stream` (``dyn`` from
    :func:`~repro.core.simulator.draw_dynamics_fleet`).  With
    ``aux_task_axis=True`` every aux leaf carries a leading task axis
    (``Policy.prepare_fleet`` — recruit-aware block allocations) and the
    per-task slice is what reaches the hooks as ``ctx.aux``.

    Returns ``(outs, psummary)``: outs holds (T, N, M) trace arrays (tr,
    idle, tx, arrive, beta, lost, backoff, queue_delay, and ``sym_id``
    for decoder policies), the (N, M) per-round ``contention`` counts,
    ``tx_end`` (T, N) and ``busy_end`` (N,); psummary is the policy
    summary with a leading task axis.
    """
    Bx, Br, Back, alpha = cfg_static
    cfg = ccp_mod.CCPConfig(Bx=Bx, Br=Br, Back=Back, alpha=alpha)
    Tt, N, M = beta.shape
    discipline = fleet_static
    aux = {} if aux is None else aux
    churn = churn_static is not None
    ge_on = cell_on = False
    outage_dist = "phase"
    rtt_dist = "off"
    max_backoff = None
    if churn:
        (period, max_backoff, outage_dist, ge_on,
         cell_on, rtt_dist) = engine._parse_churn_static(churn_static)
        window = period * dyn["speed"].shape[1]
    rtt_on = rtt_dist != "off"
    use_dec = bool(policy.uses_decoder)
    if use_dec and aux_task_axis:
        raise NotImplementedError(
            "fleet_aux='per_task' is incompatible with uses_decoder: the "
            "decoder tables/state0 under aux must be shared")

    bcast = lambda v: jnp.broadcast_to(v[None], (Tt,) + jnp.shape(v))
    carry0 = dict(
        # A stream exists iff recruited; non-recruited streams are the
        # standard stopped-stream sentinel (tx = +inf, never sends).
        tx=jnp.where(recruit, release[:, None], jnp.inf),
        busy=jnp.zeros(N),
        tr_prev=jnp.zeros((Tt, N)),
        pstate=jax.tree_util.tree_map(bcast, policy.init(N)),
    )
    if use_dec:
        carry0["dec"] = jax.tree_util.tree_map(
            bcast, aux["decoder"]["state0"])
        carry0["dec_t_hi"] = jnp.zeros(Tt)
        carry0["dec_t_done"] = jnp.full(Tt, jnp.inf)
        carry0["sym_next"] = jnp.zeros(Tt, jnp.int32)

    mv = lambda v: jnp.moveaxis(v, -1, 0)  # (T, N, M) -> (M, T, N)
    xs = dict(beta=mv(beta), d_up=mv(d_up), d_ack=mv(d_ack),
              d_down=mv(d_down), i=jnp.arange(M))
    if churn:
        xs["drop"] = mv(dyn["drop"])
    if ge_on:
        carry0["ge_bad"] = dyn["ge_bad0"]          # one chain per helper
        xs["ge_u_trans"] = dyn["ge_u_trans"].T     # (M, N) shared advance
        xs["ge_u_loss"] = mv(dyn["ge_u_loss"])     # (M, T, N) per tenant
    if rtt_on:
        xs["rtt_jit"] = mv(dyn["rtt_jit"])         # (M, T, N) per tenant
        xs["ack_u"] = mv(dyn["ack_u"])             # rtt_base stays shared

    def step(carry, x):
        tx = carry["tx"]
        busy = carry["busy"]
        sent = jnp.isfinite(tx)
        arrive = tx + x["d_up"]
        # Dedicated-helper reference start: churn/GE state for this round
        # is evaluated here, before same-round peers serialize (module
        # doc); at T=1 this IS the single-task start.
        t_sta0 = jnp.maximum(arrive, busy[None, :])
        t_arr = jnp.where(sent, arrive, 0.0)
        t_sta = jnp.where(sent, t_sta0, 0.0)
        if churn:
            beta_i, lost = jax.vmap(
                lambda bx, dr, ta, ts, sn: engine._churn_step(
                    dyn, a, bx, dr, ta, ts, sn, period=period,
                    window=window, outage_dist=outage_dist, cell_on=cell_on)
            )(x["beta"], x["drop"], t_arr, t_sta, sent)
        else:
            beta_i = x["beta"]
            lost = jnp.zeros((Tt, N), bool)
        if ge_on:
            lost_ge, ge_bad_next = engine._ge_step(
                carry["ge_bad"], dyn["ge_params"], x["ge_u_trans"],
                x["ge_u_loss"], sent)
            lost = lost | lost_ge
        received = ~lost & sent

        # --- shared-helper serialization: this round's tenants queue ---
        demand = jnp.where(received, beta_i, 0.0)
        if discipline == "priority":
            order_key = jnp.broadcast_to(prio[:, None], (Tt, N))
        else:
            order_key = arrive
        start_q, fin_q, idle, busy_next = queues.serve_round(
            arrive, demand, received, busy, order_key, discipline)
        start = jnp.where(received, start_q, t_sta0)
        # Lost packets never occupy the helper; their hypothetical return
        # (for the policy's timeout arithmetic) assumes the dedicated start.
        tr_ok = jnp.where(received, fin_q, t_sta0 + beta_i) + x["d_down"]
        tr = jnp.where(received, tr_ok, jnp.inf)
        queue_delay = jnp.where(received, start_q - t_sta0, 0.0)
        contention = received.sum(axis=0).astype(jnp.int32)
        rtt_ack = x["d_up"] + x["d_ack"]

        # Transport delay line, exactly as in the single-task scan: the
        # (T, N) jitter/ACK draws broadcast against the shared (N,)
        # per-helper base RTT and GE chain state (docs/transport.md).
        if rtt_on:
            obs_delay = engine._transport_step(
                dyn, x, carry["ge_bad"] if ge_on else None)
            tr_obs = tr_ok + obs_delay
            rtt_obs = rtt_ack + obs_delay
        else:
            tr_obs, rtt_obs = tr_ok, rtt_ack

        if use_dec:
            ids, sym_next = jax.vmap(engine._send_time_ids)(
                carry["sym_next"], tx, sent)
            tables = aux["decoder"]["tables"]
            dec, t_hi, t_done = jax.vmap(
                lambda d, hi, dn, ii, rc, tk: engine._decode_step(
                    d, hi, dn, tables, ii, rc, tk)
            )(carry["dec"], carry["dec_t_hi"], carry["dec_t_done"], ids,
              received, tr_obs)
            dec_kw = dict(decoded_count=dec["count"], ripple=dec["ripple"],
                          decode_done=dec["done"], decode_t_done=t_done)
        else:
            dec = None
            dec_kw = {}

        # Policy hooks per tenant: StepCtx is not a pytree, so it is built
        # inside the vmapped per-task closure; cfg/contention are shared
        # (closed over), per-task slices are mapped — including the aux
        # when it carries a task axis (recruit-aware block allocations).
        def hooks_one(pstate, tx_t, arrive_t, start_t, beta_t, trok_t,
                      lost_t, recv_t, rtt_t, dup_t, ddown_t, dack_t,
                      trprev_t, qd_t, dk, aux_t):
            ctx = policies_mod.StepCtx(
                i=x["i"], n=N, tx=tx_t, arrive=arrive_t, start=start_t,
                beta=beta_t, tr_ok=trok_t, lost=lost_t, received=recv_t,
                rtt_ack=rtt_t, d_up=dup_t, d_down=ddown_t, d_ack=dack_t,
                tr_prev=trprev_t, cfg=cfg, max_backoff=max_backoff,
                aux=aux_t, queue_delay=qd_t, contention=contention, **dk)
            return engine._hook_step(policy, pstate, ctx, churn)

        aux_ax = 0 if aux_task_axis else None
        pstate, tx_next, b = jax.vmap(
            hooks_one,
            in_axes=(0,) * 14 + (0, aux_ax),
        )(carry["pstate"], tx, arrive, start, beta_i, tr_obs, lost,
          received, rtt_obs, x["d_up"], x["d_down"], x["d_ack"],
          carry["tr_prev"], queue_delay, dec_kw, aux)

        new_carry = dict(
            tx=tx_next, busy=busy_next,
            tr_prev=jnp.where(received, tr_obs, carry["tr_prev"]),
            pstate=pstate,
        )
        if ge_on:
            new_carry["ge_bad"] = ge_bad_next
        if use_dec:
            new_carry["dec"] = dec
            new_carry["dec_t_hi"] = t_hi
            new_carry["dec_t_done"] = t_done
            new_carry["sym_next"] = sym_next
        out = dict(tr=tr, idle=idle, tx=tx, arrive=arrive,
                   beta=jnp.where(sent, beta_i, 0.0), lost=lost,
                   backoff=b, queue_delay=queue_delay,
                   contention=contention)
        if use_dec:
            out["sym_id"] = ids
        return new_carry, out

    final, outs = jax.lax.scan(step, carry0, xs)
    res = {k: jnp.moveaxis(v, 0, -1) for k, v in outs.items()}
    res["tx_end"] = final["tx"]
    res["busy_end"] = final["busy"]
    pstate_final = final["pstate"]
    if jax.tree_util.tree_leaves(pstate_final):
        psum = jax.vmap(policy.summary)(pstate_final)
    else:  # stateless policy: summary({}) carries no per-helper arrays
        psum = policy.summary(pstate_final)
    if use_dec:
        psum = dict(psum, dec_count=final["dec"]["count"],
                    dec_done=final["dec"]["done"])
    return res, psum
