"""Fleet-level metrics: fairness and helper utilization.

These reduce the per-(task, helper, packet) trace of one fleet rep to the
scalars the saturation sweep plots (``benchmarks/fig_fleet.py``): how
evenly the tenants' sojourn times came out (Jain), and how busy each
helper was inside the rep's makespan.  Both are pure jnp and run inside
the jitted per-rep pipeline (``engine._fleet_one``); the batch-level p50 /
p99 reductions live host-side in ``FleetRunResult.summary()``.
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["jain_fairness", "helper_utilization"]


def jain_fairness(x, valid):
    """Jain's fairness index ``J = (sum x)^2 / (n * sum x^2)`` over the
    valid entries of ``x``: 1.0 when every tenant saw the same sojourn,
    1/n when one tenant ate the whole delay budget.  NaN when no entry is
    valid (the rep must be dropped anyway)."""
    xv = jnp.where(valid, x, 0.0)
    n = valid.sum()
    den = n * (xv ** 2).sum()
    return jnp.where(den > 0, xv.sum() ** 2 / den, jnp.nan)


def helper_utilization(beta, tr, d_down, t_end):
    """Per-helper busy fraction inside the fleet makespan ``[0, t_end]``:
    served compute work whose *finish* instant (``tr - d_down`` for a
    delivered packet) landed by ``t_end``, over ``t_end``.  ``beta`` /
    ``tr`` / ``d_down`` are (T, N, M) fleet traces (or (N, M) single-task
    ones); returns (N,).  Work a helper performs after the last certified
    completion — packets nobody needed — does not count, so an
    over-provisioned pool shows honest sub-1.0 utilization."""
    fin = tr - d_down
    served = jnp.where(jnp.isfinite(tr) & (fin <= t_end), beta, 0.0)
    axes = (0, 2) if served.ndim == 3 else (1,)
    return jnp.where(t_end > 0, served.sum(axis=axes) / t_end, 0.0)
