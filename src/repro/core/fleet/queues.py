"""Per-helper service disciplines for one event-clock round.

The fleet scan hands each helper the round's batch of tenant jobs — an
arrival time, a service demand (the churn-scaled runtime), and an active
mask — plus the helper's carried busy time, and the discipline serializes
them:

``fifo`` / ``priority``
    Non-preemptive, work-conserving, one greedy selection per job: whenever
    the server frees at time ``t`` it serves the pending (arrived,
    unserved) job with the smallest order key — the arrival time for FIFO,
    the per-task priority for ``priority`` (ties -> lowest task index) —
    and if nothing has arrived yet it idles until the earliest pending
    arrival.  Each served job runs ``start = max(arrive, t)`` to
    ``start + demand``.

``ps``
    Egalitarian processor sharing, event-exact: between consecutive events
    (a job entering at its effective arrival ``max(arrive, busy)``, or the
    minimum-remaining job finishing) the ``n`` jobs in system each progress
    at rate ``1/n``.  At a completion event the applied share is exactly
    the minimum remaining work, so the finishing job hits zero with no
    epsilon.  At most T entries + T completions happen, so ``2T + 1``
    fixed iterations reach the fixpoint; converged iterations are no-ops.

Work conservation (pinned by ``tests/test_fleet.py``): for every
discipline, ``busy_end - busy == sum(demand of active jobs) + sum(idle)``
— the server is never idle while work is queued, and every active job's
demand is served in full.

Single-tenant equivalence: with one job the three disciplines all reduce
to the dedicated-helper recurrence ``start = max(arrive, busy); finish =
start + demand; idle = max(arrive - busy, 0)`` — bit-for-bit, which is the
per-helper piece of the fleet-at-M=1 == single-task engine guarantee.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["DISCIPLINES", "serve_round"]

DISCIPLINES = ("fifo", "priority", "ps")


def _greedy_serve(arrive, demand, active, busy, order_key):
    """Non-preemptive work-conserving service of one round's (T,) jobs on
    one helper (module doc).  Returns ``(start, finish, idle, busy_end)``;
    inactive jobs keep zeros and do not advance the clock."""
    T = arrive.shape[0]
    zeros = jnp.zeros(T)

    def body(carry, _):
        t, unserved, start, fin, idle = carry
        cand = unserved & active
        serve = cand.any()
        arrived = cand & (arrive <= t)
        pick = jnp.where(
            arrived.any(),
            jnp.where(arrived, order_key, jnp.inf),
            jnp.where(cand, arrive, jnp.inf),
        )
        j = jnp.argmin(pick)  # ties -> lowest task index
        st = jnp.maximum(arrive[j], t)
        fi = st + demand[j]
        gap = jnp.maximum(arrive[j] - t, 0.0)
        start = jnp.where(serve, start.at[j].set(st), start)
        fin = jnp.where(serve, fin.at[j].set(fi), fin)
        idle = jnp.where(serve, idle.at[j].set(gap), idle)
        unserved = jnp.where(serve, unserved.at[j].set(False), unserved)
        t = jnp.where(serve, fi, t)
        return (t, unserved, start, fin, idle), None

    (t, _, start, fin, idle), _ = jax.lax.scan(
        body, (busy, active, zeros, zeros, zeros), None, length=T)
    return start, fin, idle, t


def _ps_serve(arrive, demand, active, busy, order_key):
    """Event-exact egalitarian processor sharing (module doc).  A job's
    ``start`` is its entry instant ``max(arrive, busy)``; its ``finish``
    stretches with the number of concurrent jobs.  ``order_key`` is unused
    (PS has no order).  Demands must be positive (the engine's runtimes
    are ``a + eps/mu > 0``); a zero-demand active job would never finish."""
    del order_key
    T = arrive.shape[0]
    entry = jnp.where(active, jnp.maximum(arrive, busy), jnp.inf)

    def body(_, carry):
        t, rem, start, fin, idle = carry
        in_sys = active & (entry <= t) & (rem > 0.0)
        n = in_sys.sum().astype(rem.dtype)
        pending = active & (entry > t) & (rem > 0.0)
        t_entry = jnp.min(jnp.where(pending, entry, jnp.inf))
        m = jnp.min(jnp.where(in_sys, rem, jnp.inf))
        t_comp = jnp.where(n > 0, t + m * n, jnp.inf)
        te = jnp.minimum(t_entry, t_comp)
        go = jnp.isfinite(te)
        # Service over [t, te): at a completion event the share is exactly
        # m, so the minimum-remaining job hits zero with no epsilon.
        share = jnp.where(t_comp <= t_entry, m, (te - t) / jnp.maximum(n, 1.0))
        rem2 = jnp.where(in_sys, jnp.maximum(rem - share, 0.0), rem)
        fin2 = jnp.where(in_sys & (rem2 <= 0.0), te, fin)
        entering = pending & (entry <= te)
        # An empty server idles from t to te; attribute the gap to the jobs
        # that end it (split evenly, so per-helper idle sums stay exact).
        gap = jnp.where(n > 0, 0.0, te - t)
        k_in = jnp.maximum(entering.sum().astype(gap.dtype), 1.0)
        idle2 = jnp.where(entering, idle + gap / k_in, idle)
        start2 = jnp.where(entering, entry, start)
        nxt = (te, rem2, start2, fin2, idle2)
        return jax.tree_util.tree_map(
            lambda new, old: jnp.where(go, new, old), nxt, carry)

    start0 = jnp.where(active & (entry <= busy), entry, 0.0)
    init = (busy, jnp.where(active, demand, 0.0), start0,
            jnp.zeros(T), jnp.zeros(T))
    t, _rem, start, fin, idle = jax.lax.fori_loop(0, 2 * T + 1, body, init)
    return start, fin, idle, t


def serve_round(arrive, demand, active, busy, order_key, discipline: str):
    """Serialize one round's jobs on every helper under ``discipline``.

    arrive / demand / active / order_key: (T, N) per-(task, helper) job
    attributes (inactive jobs are ignored); busy: (N,) per-helper free
    time.  Returns ``(start, finish, idle, busy_end)`` with start / finish
    / idle (T, N) (zeros for inactive jobs) and busy_end (N,).
    """
    if discipline not in DISCIPLINES:
        raise ValueError(
            f"unknown discipline {discipline!r}; known: {DISCIPLINES}"
        )
    fn = _ps_serve if discipline == "ps" else _greedy_serve
    return jax.vmap(fn, in_axes=(1, 1, 1, 0, 1), out_axes=(1, 1, 1, 0))(
        arrive, demand, active, busy, order_key)
