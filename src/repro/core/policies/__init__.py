"""First-class offloading policies for the simulation engine.

Every policy is a frozen-dataclass plugin implementing the
:class:`~repro.core.policies.base.Policy` protocol and registered under a
string name; :func:`get`/:func:`names` are the registry surface used by
``Engine.run`` and the benchmark ``--policies`` flag.

Built-ins: ``ccp`` (Algorithm 1), ``best`` (oracle TTI), ``naive`` /
``naive_oracle`` (stop-and-wait with static / oracle ARQ timer),
``uncoded_mean`` / ``uncoded_mu`` and ``hcmm`` (block baselines, ported
from the sequential NumPy path into the vmapped scan), ``adaptive_rate``
(measured-loss code-rate adaptation), ``rateless_ccp`` (decoder-in-the-loop
completion: the task is done when the LT peeling decode actually succeeds),
``adaptive_rate_fb`` (code-rate adaptation that also stops sending —
drops the residual K — on ``StepCtx.decode_done``), and ``tfrc_ccp``
(RFC 5348 equation-based pacing from a scan-carried loss-event-rate and
RTT estimator, built for the delayed/lossy feedback channel of
:mod:`repro.core.transport`).

See ``docs/policies.md`` for the protocol contract and a worked example
of registering a custom policy.
"""

from .base import RING, Policy, StepCtx, get, names, register  # noqa: F401

# Importing the modules registers the built-ins.
from . import (  # noqa: F401, E402
    adaptive_rate, best, ccp, hcmm, naive, rateless, tfrc, uncoded,
)
from .adaptive_rate import AdaptiveRatePolicy  # noqa: F401
from .best import BestPolicy  # noqa: F401
from .ccp import CCPPolicy  # noqa: F401
from .hcmm import HCMMPolicy  # noqa: F401
from .naive import NaivePolicy  # noqa: F401
from .rateless import RatelessCCPPolicy  # noqa: F401
from .tfrc import TFRCCCPPolicy  # noqa: F401
from .uncoded import UncodedPolicy  # noqa: F401

__all__ = [
    "RING", "Policy", "StepCtx", "get", "names", "register",
    "CCPPolicy", "BestPolicy", "NaivePolicy", "UncodedPolicy",
    "HCMMPolicy", "AdaptiveRatePolicy", "RatelessCCPPolicy",
    "TFRCCCPPolicy",
]
