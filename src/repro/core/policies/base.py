"""First-class offloading policies: protocol, step context, and registry.

A :class:`Policy` packages everything that distinguishes one offloading
strategy from another — when to send the next packet, how to react to a
computed-packet receipt or a loss, and how to declare the task complete —
while the *scenario dynamics* (helper draws, link/compute timing, churn)
stay in :mod:`repro.core.engine`.  The engine's ``lax.scan`` step calls the
policy hooks with a :class:`StepCtx`, so every policy runs jitted, vmapped
over Monte-Carlo reps, and device-sharded through the same code path.

Protocol contract (all hooks must be pure and trace-compatible — jnp ops
only, no Python branches on traced values):

``prepare(cfg, R, ccp_cfg, mu, a, rate) -> aux``
    Per-rep auxiliary pytree computed once before the stream from the
    helper draw (e.g. the Naive ARQ timer, the uncoded/HCMM block loads).
    Traced; must be deterministic in its inputs.
``init(n) -> state``
    Per-helper policy state pytree carried through the scan.
``on_computed(state, ctx) -> state``
    Process the (possible) receipt of packet ``ctx.i``'s computed result.
    ``ctx.received`` masks helpers whose packet actually arrived.
``next_load(state, ctx) -> tx_next``
    The pacing decision: the send time of packet ``i+1`` per helper (N,).
``on_timeout(state, ctx, tx_next) -> (state, tx_retx)``
    Only invoked under churn.  React to lost packets (``ctx.lost``) and
    return the retransmission send time; the engine applies it as
    ``where(lost, tx_retx, tx_next)``.  Default: no reaction.
``finalize(outs, aux, cfg, R, kk, tx_end) -> (T, valid)``
    Completion rule.  Default: the fountain-coded (R+K)-th order statistic
    (:func:`repro.core.simulator.completion_time`).  Block-assignment
    policies override (every/enough helpers must finish their block).
``packet_mask(aux, n, m) -> (N, M) bool | None``
    Which simulated packets physically exist (block policies send only
    ``loads[n]``); ``None`` means all.  Masked packets are excluded from
    the per-helper efficiency/contribution statistics.
``backoff(state) -> (N,) | None``
    Current timeout-backoff factor for the trace (None -> ones).
``summary(state) -> dict``
    Per-helper scalars from the final policy state, surfaced in
    :class:`repro.core.engine.RunResult` extras (e.g. ``adaptive_rate``'s
    measured loss estimate).
``horizon_hint(cfg, R, kk) -> int | None``
    Optional scan-horizon hint: an upper-bound guess on the packets per
    helper the policy actually needs.  Block policies send only ~R/N
    packets per helper, so hinting a small horizon cuts their scan cost
    ~4x; the engine still doubles the horizon (up to ``m_cap_factor *
    kk``) whenever certification fails, so an under-estimate costs one
    re-run, never correctness.  ``None`` (default): the engine's shared
    heuristic.

Decoder feedback (``uses_decoder = True``): the engine additionally runs
the incremental peeling decoder of :mod:`repro.core.decode` inside the
scan and exposes ``StepCtx.decoded_count`` / ``StepCtx.ripple`` /
``StepCtx.decode_done`` to every hook.  Such a policy's ``prepare`` must
return the decode tables under ``aux["decoder"]`` as ``{"tables":
decode.make_tables(code), "state0": decode.init_state(R, tables)}`` (see
``policies/rateless.py``); its ``finalize`` typically replaces the packet
count with :func:`repro.core.decode.decode_completion`.  A policy may
stop a helper's stream by returning ``+inf`` from ``next_load`` — the
engine treats never-sent packets as non-events (not losses, no idle, no
decoder absorb).

Event-clock fleet runs (``Engine.run_fleet``) drive the *same* hooks once
per tenant per round, with ``StepCtx.queue_delay`` / ``StepCtx.contention``
populated (None on the single-task path); a policy written against this
contract needs no change to run under contention.  Fixed-allocation block
policies additionally declare ``fleet_aux = "per_task"`` so
:meth:`Policy.prepare_fleet` re-allocates their loads over each tenant's
recruit set.  See docs/fleet.md and the event-clock section of
docs/policies.md.

Policies are frozen dataclasses (hashable) so a policy instance can be a
static jit argument; per-rep data must flow through ``aux``/``state``,
never through instance attributes.

Registry: ``register(cls)`` adds a policy class under its ``name``;
``get(name)`` instantiates; ``names()`` lists.  Unknown names raise with
the known list, so a typo in ``--policies`` fails loudly.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax.numpy as jnp

__all__ = ["RING", "StepCtx", "Policy", "register", "get", "names"]

RING = 16  # ring-buffer slots for in-flight (Tr, TTI) pairs


@dataclasses.dataclass
class StepCtx:
    """Per-packet step context handed to the policy hooks.

    All array fields are (N,) slices for packet ``i``; the ctx never
    crosses a jit boundary (it is built and consumed inside one traced
    scan step), so it needs no pytree registration.
    """

    i: jnp.ndarray          # packet index (scalar)
    n: int                  # helper count
    tx: jnp.ndarray         # send time of packet i
    arrive: jnp.ndarray     # uplink arrival time
    start: jnp.ndarray      # compute start (FIFO queue)
    beta: jnp.ndarray       # effective runtime (churn-scaled)
    # Observation-delay contract (docs/transport.md): with the transport
    # layer on (ChurnConfig.rtt_dist != 'off'), tr_ok / rtt_ack / tr_prev
    # — and the decoder feedback below — are *observed* instants: the
    # physical event shifted by the sampled feedback delay (one RTT, two
    # when the ACK was lost and NACK-retransmitted).  Ground truth (the
    # engine's trace, completion extraction) stays time-exact; a policy
    # paces on what the controller can actually know.  With transport
    # off — or rtt_mean = 0 — observed and physical coincide, bit for bit.
    tr_ok: jnp.ndarray      # (observed) result-arrival time if not lost
    lost: jnp.ndarray       # bool: packet lost (churn)
    received: jnp.ndarray   # bool: ~lost
    rtt_ack: jnp.ndarray    # (observed) receipt-ACK RTT sample
    d_up: jnp.ndarray       # uplink delay of packet i
    d_down: jnp.ndarray     # result downlink delay
    d_ack: jnp.ndarray      # ACK downlink delay
    tr_prev: jnp.ndarray    # Tr of the previous *received* packet
    cfg: object             # repro.core.ccp.CCPConfig
    max_backoff: Optional[float]  # churn backoff cap (None when static)
    aux: dict               # policy.prepare() output
    # Decoder feedback (populated only when policy.uses_decoder; else None).
    # Step-aligned: reflects every result absorbed through scan step i, the
    # latest information a collector decoding eagerly could have fed back.
    decoded_count: Optional[jnp.ndarray] = None  # () i32 recovered sources
    ripple: Optional[jnp.ndarray] = None         # () i32 released this step
    decode_done: Optional[jnp.ndarray] = None    # () bool all R recovered
    # Real-time upper bound on the decode completion instant: the max
    # *observed* arrival time over the absorbed set when decode_done first
    # fired — under transport this is the master-observed bound, lagging
    # the physical decode by the feedback delay of the closing packet (+inf
    # until then).  The scan is step-aligned, not time-aligned — a slow
    # helper's step-s result can arrive *later* than a fast helper's
    # step-s+k one — so a send at tx < decode_t_done may still beat the
    # decodable set already in flight; only sends at tx >= decode_t_done
    # are provably useless.  Stop rules must gate on this, not on
    # decode_done alone.
    decode_t_done: Optional[jnp.ndarray] = None  # () f32 (+inf before done)
    # Fleet contention observability (populated only by the event-clock
    # fleet scan, :mod:`repro.core.fleet.stream`; None on the dedicated
    # single-task path).  ``queue_delay`` is how long this packet waited
    # behind other tenants at its helper (compute start minus the start it
    # would have seen on a dedicated pool); ``contention`` is how many
    # tenants each helper served this round (shared across tasks).  CCP's
    # pacing already *feels* queueing through the inflated ``tr_ok`` — these
    # fields let a policy tell contention apart from slow compute.
    queue_delay: Optional[jnp.ndarray] = None  # (N,) f32 cross-tenant wait
    contention: Optional[jnp.ndarray] = None   # (N,) i32 tenants this round


class Policy:
    """Base policy: every hook has the neutral default (see module doc)."""

    name: str = "base"
    version: int = 1
    #: horizon-cap multiple of R+K (None -> engine default: 1 static/4 churn)
    m_cap_factor: Optional[int] = None
    #: True -> the engine runs the incremental peeling decoder in the scan
    #: and populates StepCtx.decoded_count/ripple/decode_done (module doc).
    uses_decoder: bool = False
    #: Fleet-run aux layout: "shared" (one ``prepare`` aux for every
    #: tenant — rateless policies adapt to whatever streams are open) or
    #: "per_task" (``prepare_fleet`` builds one aux per tenant so
    #: fixed-allocation block policies see their recruit set; see
    #: docs/fleet.md).  Incompatible with ``uses_decoder``.
    fleet_aux: str = "shared"

    def prepare(self, cfg, R: int, ccp_cfg, mu, a, rate) -> dict:
        return {}

    def prepare_fleet(self, cfg, R: int, ccp_cfg, mu, a, rate, recruit):
        """Per-tenant aux for ``Engine.run_fleet`` (only called when
        ``fleet_aux == "per_task"``): stacks one :meth:`prepare` aux per
        task, with non-recruited helpers' mu zeroed so every
        weight-proportional block allocation lands on the task's actual
        recruit set (1/E[beta] and mu weights both vanish at mu=0)."""
        import jax  # local: base.py is otherwise jnp-only

        return jax.vmap(
            lambda r: self.prepare(
                cfg, R, ccp_cfg, jnp.where(r, mu, 0.0), a, rate)
        )(recruit)

    def init(self, n: int):
        return {}

    def on_computed(self, state, ctx: StepCtx):
        return state

    def next_load(self, state, ctx: StepCtx) -> jnp.ndarray:
        raise NotImplementedError(f"{type(self).__name__}.next_load")

    def on_timeout(self, state, ctx: StepCtx, tx_next) -> Tuple[object, jnp.ndarray]:
        return state, tx_next

    def finalize(self, outs, aux, cfg, R: int, kk: int, tx_end):
        from ..simulator import completion_time  # lazy: avoids import cycle
        return completion_time(outs["tr"], kk, tx_end=tx_end)

    def packet_mask(self, aux, n: int, m: int):
        return None

    def backoff(self, state):
        return None

    def summary(self, state) -> dict:
        return {}

    def horizon_hint(self, cfg, R: int, kk: int) -> Optional[int]:
        return None

    def __repr__(self) -> str:  # registry name is the canonical identity
        return f"<policy {self.name!r} v{self.version}>"


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Callable[[], Policy]] = {}


def register(name_or_cls=None, *, factory: Optional[Callable[[], Policy]] = None):
    """Register a policy class (``@register``) or a named factory
    (``register("uncoded_mu", factory=lambda: UncodedPolicy(rule="mu"))``)."""
    if isinstance(name_or_cls, str):
        name = name_or_cls
        if factory is None:
            raise ValueError("register(name, ...) requires factory=")
        _REGISTRY[name] = factory
        return factory
    cls = name_or_cls

    def _decorate(cls):
        _REGISTRY[cls.name] = cls
        return cls

    return _decorate(cls) if cls is not None else _decorate


def get(name: str) -> Policy:
    """Instantiate the registered policy ``name``; unknown names raise with
    the full known list (the ``--policies`` fail-loudly contract)."""
    if name not in _REGISTRY:
        raise ValueError(
            f"unknown policy {name!r}; known policies: {sorted(_REGISTRY)}"
        )
    return _REGISTRY[name]()


def names() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))
