"""Naive stop-and-wait (paper eq. 16), static or oracle ARQ timer.

``naive``: tx_{i+1} = Tr_i, and — under churn — a retransmission timer
statically provisioned for the slowest helper class (Naive has no
estimator, so it cannot adapt the timer per helper; that is exactly what
it pays for under churn).

``naive_oracle``: the same stop-and-wait stream but with a per-helper
*oracle* timer built from the true (unobservable) mean runtime and link
rate — it separates Naive's pipelining loss (remains) from its
timer-adaptation loss (gone) in the churn benchmarks.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from .. import ccp as ccp_mod
from .base import Policy, StepCtx, register


@dataclasses.dataclass(frozen=True)
class NaivePolicy(Policy):
    oracle: bool = False
    version = 1

    @property
    def name(self) -> str:
        return "naive_oracle" if self.oracle else "naive"

    def prepare(self, cfg, R: int, ccp_cfg, mu, a, rate) -> dict:
        if self.oracle:
            # Oracle timer: the true per-helper mean runtime + data RTT.
            to = ccp_mod.arq_timeout(
                a + 1.0 / mu, (ccp_cfg.Bx + ccp_cfg.Br) / rate
            )
        else:
            mu_min = min(cfg.mu_choices)
            a_max = (cfg.a_const if cfg.a_mode == "const" else 1.0 / mu_min)
            to = ccp_mod.arq_timeout(
                a_max + 1.0 / mu_min, (ccp_cfg.Bx + ccp_cfg.Br) / rate
            )
        return {"naive_to": to}

    def next_load(self, state, ctx: StepCtx):
        return ctx.tr_ok

    def on_timeout(self, state, ctx: StepCtx, tx_next):
        # Stop-and-wait ARQ: retransmit when the fixed timer expires.
        return state, ctx.tx + ctx.aux["naive_to"]


register("naive", factory=NaivePolicy)
register("naive_oracle", factory=lambda: NaivePolicy(oracle=True))
