"""TFRC-paced CCP: equation-based congestion control on the feedback loop.

Under the transport layer's delayed, lossy feedback channel
(:mod:`repro.core.transport`), CCP's loss reaction is a TCP-Tahoe-shaped
multiplicative backoff: every timeout doubles the effective TTI until a
receipt resets it.  That is the right response to an *outage* but — like
TCP on a wireless path — over-throttles on *burst erasures*: a
Gilbert–Elliott fade eats several packets, each doubling the pace, when
one congestion signal already carries all the information.

``tfrc_ccp`` replaces the reflexive backoff with RFC 5348 equation-based
pacing:

  * a scan-carried **loss-event-rate** estimator ``p_ev``
    (:func:`repro.core.transport.tfrc.loss_event_update`): losses within
    one RTT of the first loss of an event collapse into a single event,
    so a fade counts once however many packets it cost;
  * the **RTT estimator** is CCP's own eq.-(4) EWMA ``rtt_data`` (floored
    by the current packet's scaled ACK sample, as in the timeout
    deadline) — under transport it tracks the *observed* feedback RTT,
    which is exactly the R the TFRC equation wants;
  * pacing: while a loss event is open (an unbroken run of losses), the
    eq.-(8) send instant is floored by the TFRC minimum send interval —
    ``tx + tfrc_send_interval(p_ev, rtt)``
    (:func:`repro.core.transport.tfrc.tfrc_send_interval`) — so the flow
    never pushes into a fade faster than the TCP-fair rate for its
    measured loss-event process.  Between events the floor is off: a
    one-packet-in-flight request-response flow is already below the
    TCP-fair rate there (see ``next_load``);
  * the multiplicative backoff only engages after ``outage_run``
    consecutive losses (an outage signature the event rate cannot
    explain), mirroring ``adaptive_rate``'s loss discrimination; the
    line-14 retransmission deadline is kept — loss detection latency is
    physics, not policy.

With no losses ``p_ev`` stays 0, the TFRC floor is 0, and the policy is
bit-for-bit ``ccp`` — at any RTT (pinned by tests/test_transport.py).
Under burst loss at high RTT the event-rate response beats the reflexive
per-loss backoff on completion delay (the fig_transport smoke anchor:
tfrc_ccp <= ccp at the highest-RTT burst point), at a small efficiency
cost relative to ``ccp``'s heavier self-throttling.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from .. import ccp as ccp_mod
from ..transport import tfrc as tfrc_mod
from .base import StepCtx, register
from .ccp import CCPPolicy


@register
@dataclasses.dataclass(frozen=True)
class TFRCCCPPolicy(CCPPolicy):
    """CCP paced by the TFRC throughput equation (see module docstring)."""

    name = "tfrc_ccp"
    version = 1

    loss_ewma: float = 0.1   # EWMA weight of the loss-event-rate estimate
    p_clip: float = 0.5      # cap on p_ev entering the throughput equation
    outage_run: int = 4      # consecutive losses before backoff engages

    def init(self, n: int):
        state = super().init(n)
        return dict(
            state,
            p_ev=jnp.zeros(n),
            ev_start=jnp.full(n, -jnp.inf),
            consec=jnp.zeros(n, jnp.int32),
        )

    def _rtt_eff(self, state, ctx: StepCtx):
        """The TFRC R: CCP's EWMA feedback-RTT estimate, floored by this
        packet's scaled ACK sample (same floor as the timeout deadline,
        so a helper with no receipts yet still has a finite R)."""
        return jnp.maximum(
            state["est"].rtt_data, ctx.cfg.data_scale * ctx.rtt_ack)

    def on_computed(self, state, ctx: StepCtx):
        new = super().on_computed(state, ctx)
        # The whole p_ev update (decay on delivery, bump on a new loss
        # event) lives in on_timeout: it runs every step under churn, and
        # without churn there are no losses for p_ev to measure.
        return dict(
            new, consec=jnp.where(ctx.received, 0, state["consec"]))

    def next_load(self, state, ctx: StepCtx) -> jnp.ndarray:
        tx_ccp = super().next_load(state, ctx)
        pace = tfrc_mod.tfrc_send_interval(
            jnp.minimum(state["p_ev"], self.p_clip),
            self._rtt_eff(state, ctx))
        # The TFRC floor on the send interval, scoped to an *open loss
        # event* (an unbroken loss run, consec > 0): never send into a
        # fade faster than tx + interval(p_ev, R).  Between events a
        # one-in-flight request-response flow already sends below the
        # TCP-fair rate (interval >= beta + R > R * f(p) for any p with
        # f(p) < 1), so an always-on floor would only add idle — measured:
        # it costs ~15% completion and ~6% efficiency at rtt_mean = 4
        # versus this scoping.  At p_ev = 0 the floor is tx itself and
        # eq. (8) decides alone — bitwise ccp.
        pace = jnp.where(state["consec"] > 0, pace, 0.0)
        return jnp.maximum(tx_ccp, ctx.tx + pace)

    def on_timeout(self, state, ctx: StepCtx, tx_next):
        deadline = self._deadline(state, ctx)
        p_ev, ev_start = tfrc_mod.loss_event_update(
            state["p_ev"], state["ev_start"], ctx.lost, ctx.received,
            ctx.tx, self._rtt_eff(state, ctx), w=self.loss_ewma)
        consec = jnp.where(ctx.lost, state["consec"] + 1, state["consec"])
        # Equation-based response: the measured event rate throttles the
        # pace, so the multiplicative backoff is reserved for loss runs
        # that look like an outage, not a fade.
        est = ccp_mod.on_timeout(
            state["est"], ctx.lost & (consec >= self.outage_run),
            max_backoff=ctx.max_backoff)
        new = dict(state, est=est, p_ev=p_ev, ev_start=ev_start,
                   consec=consec)
        return new, ctx.tx + deadline

    def summary(self, state) -> dict:
        return {"p_ev": state["p_ev"]}
