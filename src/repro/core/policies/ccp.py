"""CCP (Algorithm 1) as a first-class policy.

The arithmetic is the paper-faithful port of the former ``mode="ccp"``
string branch of the PR-2 simulator: eq. (8) pacing from the ring-buffered
``E[beta]`` estimate in effect at the send instant, and — under churn —
the lines 13-14 timeout/backoff path.  The golden-equivalence tests pin
this bit-for-bit against the pre-redesign string dispatch.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from .. import ccp as ccp_mod
from .base import RING, Policy, StepCtx, register


@register
@dataclasses.dataclass(frozen=True)
class CCPPolicy(Policy):
    """Algorithm 1: estimated TTI with ring-buffer feedback delay."""

    name = "ccp"
    version = 1

    def init(self, n: int):
        return dict(
            est=ccp_mod.init_state(n),
            ring_tr=jnp.full((n, RING), jnp.inf),
            ring_tti=jnp.zeros((n, RING)),
        )

    def on_computed(self, state, ctx: StepCtx):
        est, _tti_i = ccp_mod.on_computed(
            state["est"], ctx.cfg, ctx.tx, ctx.tr_ok, ctx.tr_prev,
            ctx.rtt_ack, active=ctx.received,
        )
        slot = ctx.i % RING
        ring_tr = state["ring_tr"].at[:, slot].set(
            jnp.where(ctx.received, ctx.tr_ok, jnp.inf)
        )
        ring_tti = state["ring_tti"].at[:, slot].set(est.e_beta)
        return dict(state, est=est, ring_tr=ring_tr, ring_tti=ring_tti)

    def _select(self, state, tx):
        """E[beta] estimate in effect when planning the next send: the ring
        entry with the largest Tr among those with Tr <= tx (the latest
        information that had arrived by the current send instant)."""
        valid = state["ring_tr"] <= tx[:, None]
        masked = jnp.where(valid, state["ring_tr"], -jnp.inf)
        sel = jnp.argmax(masked, axis=1)
        has = valid.any(axis=1)
        e_beta_sel = jnp.take_along_axis(
            state["ring_tti"], sel[:, None], axis=1)[:, 0]
        return has, e_beta_sel

    def _tti_scale(self, state, ctx: StepCtx):
        """Multiplier on the estimated TTI (None = 1); the adaptive-rate
        subclass compensates the measured loss rate here."""
        return None

    def next_load(self, state, ctx: StepCtx) -> jnp.ndarray:
        # eq. (8), causal form: tx_{i+1} = min(Tr_i, tx_i + E[beta]),
        # scaled by the timeout backoff factor (1 when no timeouts).
        # Bootstrap: before any computed packet has returned by tx, the
        # collector has no estimate -> stop-and-wait on this packet.
        has, e_beta_sel = self._select(state, ctx.tx)
        tti_est = e_beta_sel * state["est"].tti_backoff
        scale = self._tti_scale(state, ctx)
        if scale is not None:
            tti_est = tti_est * scale
        return jnp.where(
            has, jnp.minimum(ctx.tr_ok, ctx.tx + tti_est), ctx.tr_ok
        )

    def _deadline(self, state, ctx: StepCtx):
        """Alg. 1 line 14 loss-detection latency: TO = 2*(TTI + RTT^data)
        with the *pre-doubling* TTI.  ``rtt_eff`` floors the RTT term with
        this packet's scaled ACK sample so helpers that never responded
        yet still have a finite deadline."""
        est = state["est"]
        has, e_beta_sel = self._select(state, ctx.tx)
        rtt_eff = jnp.maximum(est.rtt_data, ctx.cfg.data_scale * ctx.rtt_ack)
        tti_pre = jnp.where(has, e_beta_sel, rtt_eff) * est.tti_backoff
        return ccp_mod.timeout_deadline(est.replace(rtt_data=rtt_eff), tti_pre)

    def on_timeout(self, state, ctx: StepCtx, tx_next):
        # Alg. 1 lines 13-14 for a lost packet: the loss is detected when
        # TO elapses, the stream resumes then, and the backoff doubles
        # (capped) for the following sends.  Consecutive losses therefore
        # space out geometrically and a receipt (on_computed) resets the
        # backoff — so a helper that rejoins is re-ramped.
        deadline = self._deadline(state, ctx)
        est = ccp_mod.on_timeout(
            state["est"], ctx.lost, max_backoff=ctx.max_backoff
        )
        return dict(state, est=est), ctx.tx + deadline

    def backoff(self, state):
        return state["est"].tti_backoff
