"""Uncoded baseline (paper §6) as an in-scan policy.

``r_n`` *uncoded* packets are pre-assigned to helper ``n`` (summing to
exactly R — no coding, so *every* helper must finish its block).  Two
allocation rules from the paper: proportional to 1/E[beta_n] ('mean') and
proportional to mu_n ('mu').

Ported from the sequential NumPy path in :mod:`repro.core.baselines` into
the engine scan, so the baseline runs vmapped over Monte-Carlo reps and
device-sharded for the first time.  The stream is back-to-back uplink
serialization (tx_{i+1} = tx_i + d_up_i, i.e. arrive = cumsum(d_up)), the
completion rule is ``max_n Tr_{n, loads_n}``, and a lost packet (churn)
makes its helper's block — hence the whole task — unfinishable (no ARQ,
no coding: T = inf), which is exactly the brittleness CCP's fountain
coding removes.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from .base import Policy, StepCtx, register


def largest_remainder_round(loads, total):
    """Trace-compatible largest-remainder rounding: non-negative real
    ``loads`` -> int32 loads summing exactly to ``total`` (traced scalar
    ok).  Ties broken by helper index (stable argsort), matching the NumPy
    :func:`repro.core.theory.largest_remainder_round` up to tie order."""
    base = jnp.floor(loads)
    short = (jnp.round(total) - base.sum()).astype(jnp.int32)
    frac = loads - base
    order = jnp.argsort(-frac)
    bump = (jnp.arange(loads.shape[0]) < short).astype(base.dtype)
    add = jnp.zeros_like(base).at[order].set(bump)
    return (base + add).astype(jnp.int32)


def block_finish_times(outs, loads):
    """Per-helper block finish time from the scan outputs: the Tr of the
    last assigned packet, or +inf if any packet of the block was lost
    (churn; there is no retransmission), or 0 for an empty block."""
    tr = outs["tr"]
    m = tr.shape[1]
    mask = jnp.arange(m)[None, :] < loads[:, None]
    idx = jnp.clip(loads - 1, 0, m - 1)
    t_last = jnp.take_along_axis(tr, idx[:, None], axis=1)[:, 0]
    lost_any = (mask & ~jnp.isfinite(tr)).any(axis=1)
    return jnp.where(
        loads > 0, jnp.where(lost_any, jnp.inf, t_last), 0.0
    )


@dataclasses.dataclass(frozen=True)
class UncodedPolicy(Policy):
    rule: str = "mean"
    version = 1
    m_cap_factor = 4
    report_aux = ("loads",)
    # Fixed pre-assigned blocks must be allocated over each tenant's
    # recruited helpers, not the whole pool: a block stranded on a
    # non-recruited (stopped) stream would make the task unfinishable.
    fleet_aux = "per_task"

    @property
    def name(self) -> str:
        return f"uncoded_{self.rule}"

    def prepare(self, cfg, R: int, ccp_cfg, mu, a, rate) -> dict:
        if self.rule == "mean":
            w = 1.0 / (a + 1.0 / mu)
        elif self.rule == "mu":
            w = mu
        else:
            raise ValueError(f"unknown uncoded rule {self.rule!r}")
        return {"loads": largest_remainder_round(R * w / w.sum(), R)}

    def horizon_hint(self, cfg, R: int, kk: int):
        """Block policies send ~R/N packets per helper, not the engine's
        CCP-sized M: hint the expected largest block (the fastest helper
        class's share of R under this policy's *own* allocation weights)
        with headroom, bucketed to a power of two.  A helper draw whose
        block exceeds the hint fails certification (``loads.max() > M``)
        and the engine doubles M — one re-run, never a wrong result."""
        from .. import simulator  # lazy: avoids import cycle at registration

        mu, _a, w_mean = simulator.class_weights(cfg)
        # same weights prepare() allocates with ('mean' also approximates
        # the HCMM lambda* well enough for a hint — certification backstops)
        w = mu if self.rule == "mu" else w_mean
        share = float(w.max() / (cfg.N * w.mean()))
        m = int(np.ceil(1.5 * kk * share)) + 32
        return 1 << int(np.ceil(np.log2(max(m, 32))))

    def next_load(self, state, ctx: StepCtx):
        # Back-to-back uplink: send packet i+1 the moment packet i's
        # transmission finishes (arrive_i = cumsum(d_up)_i).
        return ctx.tx + ctx.d_up

    def on_timeout(self, state, ctx: StepCtx, tx_next):
        # No ARQ: a lost packet is simply gone; keep streaming the block.
        return state, ctx.tx + ctx.d_up

    def packet_mask(self, aux, n: int, m: int):
        return jnp.arange(m)[None, :] < aux["loads"][:, None]

    def finalize(self, outs, aux, cfg, R: int, kk: int, tx_end):
        t_n = block_finish_times(outs, aux["loads"])
        valid = aux["loads"].max() <= outs["tr"].shape[1]
        return t_n.max(), valid


register("uncoded_mean", factory=UncodedPolicy)
register("uncoded_mu", factory=lambda: UncodedPolicy(rule="mu"))
