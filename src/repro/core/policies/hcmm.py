"""HCMM baseline (arXiv:1701.05973, Reisizadeh et al.) as an in-scan policy.

Each helper gets a fixed block of MDS-coded rows, sized by the
asymptotically-optimal load; the collector finishes when the loads of
*fully finished* helpers sum to >= R.  Load solver (vectorized Newton,
trace-compatible): helper n's per-time expected useful rate is
``rho(lmbda) = lmbda * (1 - e^{mu a - mu/lmbda})``; the optimum ``lmbda*``
solves ``ln(1 + u + mu*a) = u`` with ``u = mu/lmbda - mu*a``, then
``tau* = R / sum_n rho_n(lmbda_n*)`` and ``ell_n = lmbda_n* tau*``.

Ported from the sequential NumPy path in :mod:`repro.core.baselines` so
the baseline runs vmapped/sharded through the same engine as CCP; the
stream/timing model is shared with :class:`~.uncoded.UncodedPolicy`
(back-to-back uplink, no ARQ), only the completion rule differs — partial
redundancy lets HCMM survive slow helpers, and under churn a helper whose
block lost a packet simply never counts toward the R-row threshold.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .base import StepCtx, register
from .uncoded import UncodedPolicy, block_finish_times, largest_remainder_round


def u_star(mu_a):
    """Solve ``ln(1 + u + mu*a) = u`` for u > 0, elementwise (Newton with
    the same iteration as the NumPy solver; converged lanes are at a fixed
    point, so extra iterations are no-ops)."""

    def body(_, u):
        f = jnp.log1p(u + mu_a) - u
        fp = 1.0 / (1.0 + u + mu_a) - 1.0
        # fp underflows to exactly 0 in f32 once u + mu_a < ~1e-7 — the
        # mu = 0 masked-helper lane of a fleet allocation — and f is 0
        # there too: hold the fixed point instead of dividing 0/0.
        fp_safe = jnp.where(fp < 0, fp, -1.0)
        u_new = jnp.where(fp < 0, u - f / fp_safe, u)
        return jnp.where(u_new <= 0, u / 2.0, u_new)

    return jax.lax.fori_loop(0, 64, body, jnp.maximum(mu_a, 1.0))


def hcmm_loads(R, mu, a):
    """HCMM asymptotically-optimal per-helper integer loads (traced)."""
    mu_a = mu * a
    u = u_star(mu_a)
    lam = mu / (u + mu_a)
    rho = lam * (1.0 - jnp.exp(-u))
    tau = R / rho.sum()
    loads = lam * tau
    return largest_remainder_round(loads, jnp.ceil(loads.sum()))


@register
@dataclasses.dataclass(frozen=True)
class HCMMPolicy(UncodedPolicy):
    """Fixed MDS blocks, completion at aggregate finished load >= R."""

    name = "hcmm"
    version = 1

    def prepare(self, cfg, R: int, ccp_cfg, mu, a, rate) -> dict:
        return {"loads": hcmm_loads(R, mu, a)}

    def finalize(self, outs, aux, cfg, R: int, kk: int, tx_end):
        loads = aux["loads"]
        t_n = block_finish_times(outs, loads)
        order = jnp.argsort(t_n)
        agg = jnp.cumsum(loads[order])
        pos = jnp.clip(jnp.searchsorted(agg, R), 0, loads.shape[0] - 1)
        valid = loads.max() <= outs["tr"].shape[1]
        return t_n[order][pos], valid
