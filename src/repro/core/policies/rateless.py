"""Rateless CCP: completion by *actual* LT decode success, not packet count.

Counter-based CCP declares the task done at the (R+K)-th received packet —
an idealized MDS abstraction that hides the LT code's overhead randomness
(the paper's own O(R) Raptor argument concedes the decode is probabilistic).
``rateless_ccp`` keeps Algorithm 1's pacing bit-for-bit but runs the
incremental peeling decoder of :mod:`repro.core.decode` in the loop:

* every send slot carries a fresh coded symbol (helper ``n``'s packet ``i``
  is global id ``i*N + n`` — systematic for ids < R, then a parity pool);
* the engine absorbs each arrival into the scan-carried ``DecoderState``
  and feeds ``decoded_count / ripple / decode_done`` back through
  :class:`~repro.core.policies.base.StepCtx`;
* ``finalize`` binary-searches the time-sorted arrival prefix for the first
  decodable set (:func:`repro.core.decode.decode_completion`) — the honest
  completion delay, which can *beat* the counter (a decodable set can form
  before R+K arrivals) or trail it (a peeling stall needs extra symbols).

The measured per-rep LT overhead is therefore observable as
``r_n.sum() - R`` (arrivals the decoder actually consumed minus sources) —
the quantity ``benchmarks/fig_decode.py`` sweeps against the offline
robust-soliton failure statistics (arXiv:2103.04247 and arXiv:1909.12611
adapt to exactly this feedback signal: what the decoder has recovered, not
what a counter assumed).
"""

from __future__ import annotations

import dataclasses

from .. import decode as decode_mod
from .base import register
from .ccp import CCPPolicy


@register
@dataclasses.dataclass(frozen=True)
class RatelessCCPPolicy(CCPPolicy):
    """Algorithm-1 pacing + decoder-in-the-loop completion (module doc)."""

    name = "rateless_ccp"
    version = 1
    uses_decoder = True

    def prepare(self, cfg, R: int, ccp_cfg, mu, a, rate) -> dict:
        aux = super().prepare(cfg, R, ccp_cfg, mu, a, rate)
        # The pool is built host-side from static ints (R), shared across
        # Monte-Carlo reps like a task-id-seeded production code, and closed
        # over by the trace as one constant.
        return dict(aux, decoder=decode_mod.decoder_aux(R))

    def finalize(self, outs, aux, cfg, R: int, kk: int, tx_end):
        return decode_mod.finalize_decode(outs, aux, R, tx_end)
