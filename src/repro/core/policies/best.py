"""Best (genie) policy: oracle TTI_{n,i} = beta_{n,i} (paper eq. 13)."""

from __future__ import annotations

import dataclasses

from .base import Policy, StepCtx, register


@register
@dataclasses.dataclass(frozen=True)
class BestPolicy(Policy):
    """Oracle pacing: the collector magically knows each packet's runtime,
    so the next send lands exactly when the helper frees up.  Under churn
    the oracle keeps its pacing (a lost packet costs its runtime slot but
    triggers no timeout stall) — the lower envelope the adaptive policies
    are measured against."""

    name = "best"
    version = 1

    def next_load(self, state, ctx: StepCtx):
        return ctx.tx + ctx.beta

    def on_timeout(self, state, ctx: StepCtx, tx_next):
        return state, ctx.tx + ctx.beta
