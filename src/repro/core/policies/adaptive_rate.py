"""Code-rate adaptation under churn (arXiv:2103.04247-style).

CCP reacts to every lost packet as if the helper had stalled: the Alg. 1
line-13 backoff doubles the effective TTI, which is right for outages but
wasteful under *channel erasures* — with a rateless fountain code a lost
packet needs no retransmission, just one more coded packet, so the right
response to measured loss rate ``p`` is to raise the sending overhead by
``1/(1-p)`` (adapt the realized code rate) and keep the pipeline full.

``adaptive_rate`` extends :class:`~.ccp.CCPPolicy` with an EWMA estimate
``p_hat`` of the per-helper loss process:

  * **pacing** — the eq. (8) TTI is scaled by ``(1 - min(p_hat, p_clip))``:
    a helper measured at 20% loss is fed ~1.25x more coded packets, so the
    *useful* delivery rate stays matched to its service rate.  The
    realized fountain overhead ``K_eff = sent - received`` thereby tracks
    the loss process instead of being fixed at provisioning time.
  * **loss discrimination** — the multiplicative timeout backoff only
    engages after ``outage_run`` *consecutive* losses (a run that the
    measured erasure rate cannot explain, i.e. an outage); isolated and
    bursty erasures pay the detection deadline but never the exponential
    stall.  A receipt still resets the backoff, so rejoin re-ramps.

Under the Gilbert–Elliott burst-loss regime this beats fixed-K CCP's
completion delay (pinned by the fig_churn smoke lane); under pure outages
(``consec >= outage_run``) it degenerates to CCP's capped backoff.

Decoder feedback (``adaptive_rate_fb``)
---------------------------------------
With ``decoder_feedback=True`` the policy closes the remaining loop the
ROADMAP asked for: the engine runs the incremental peeling decoder of
:mod:`repro.core.decode` in the scan and the policy *drops the residual
overhead* the moment ``StepCtx.decode_done`` fires — ``next_load`` returns
``+inf`` (stop sending), so the provisioned K sheds to the K the decode
actually needed, and ``finalize`` reports the honest decode-success
completion time instead of the packet count.  With ``decoder_feedback=False``
(the registered ``adaptive_rate``) the policy is bit-for-bit the PR-3
send-side adapter, so the zero-churn == CCP pin still holds.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from .. import ccp as ccp_mod
from .. import decode as decode_mod
from .base import StepCtx, register
from .ccp import CCPPolicy


@dataclasses.dataclass(frozen=True)
class AdaptiveRatePolicy(CCPPolicy):
    """CCP + measured-loss code-rate adaptation (see module docstring)."""

    version = 1

    loss_ewma: float = 0.1   # EWMA weight of the per-helper loss estimate
    p_clip: float = 0.5      # cap on the rate-compensation (overhead <= 2x)
    outage_run: int = 4      # consecutive losses before backoff engages
    #: close the loop with the fountain decoder: stop sending (drop the
    #: residual K) on StepCtx.decode_done and finalize at decode success
    decoder_feedback: bool = False

    @property
    def name(self) -> str:
        return "adaptive_rate_fb" if self.decoder_feedback else "adaptive_rate"

    @property
    def uses_decoder(self) -> bool:
        return self.decoder_feedback

    def init(self, n: int):
        state = super().init(n)
        return dict(state, p_hat=jnp.zeros(n), consec=jnp.zeros(n, jnp.int32))

    def on_computed(self, state, ctx: StepCtx):
        new = super().on_computed(state, ctx)
        w = self.loss_ewma
        return dict(
            new,
            p_hat=jnp.where(
                ctx.received, (1.0 - w) * state["p_hat"], state["p_hat"]
            ),
            consec=jnp.where(ctx.received, 0, state["consec"]),
        )

    def _tti_scale(self, state, ctx: StepCtx):
        # Code-rate adaptation: send 1/(1-p_hat) coded packets per useful
        # one, so the helper's useful delivery rate matches its service
        # rate despite the measured erasures.
        return 1.0 - jnp.minimum(state["p_hat"], self.p_clip)

    def on_timeout(self, state, ctx: StepCtx, tx_next):
        deadline = self._deadline(state, ctx)
        w = self.loss_ewma
        p_hat = jnp.where(
            ctx.lost, w + (1.0 - w) * state["p_hat"], state["p_hat"]
        )
        consec = jnp.where(ctx.lost, state["consec"] + 1, state["consec"])
        # Back off only when the loss run looks like an outage, not an
        # erasure burst the adapted code rate already absorbs.
        est = ccp_mod.on_timeout(
            state["est"], ctx.lost & (consec >= self.outage_run),
            max_backoff=ctx.max_backoff,
        )
        tx_retx = ctx.tx + deadline
        if self.decoder_feedback:
            # No point retransmitting a symbol the finished decode no
            # longer needs (same time gate as next_load).
            tx_retx = jnp.where(
                ctx.decode_done & (tx_retx >= ctx.decode_t_done),
                jnp.inf, tx_retx)
        return dict(state, est=est, p_hat=p_hat, consec=consec), tx_retx

    def prepare(self, cfg, R: int, ccp_cfg, mu, a, rate) -> dict:
        aux = super().prepare(cfg, R, ccp_cfg, mu, a, rate)
        if not self.decoder_feedback:
            return aux
        return dict(aux, decoder=decode_mod.decoder_aux(R))

    def next_load(self, state, ctx: StepCtx) -> jnp.ndarray:
        tx = super().next_load(state, ctx)
        if self.decoder_feedback:
            # Drop the residual overhead once the decode has succeeded.  The
            # gate is the *time* bound, not the step-aligned done flag: the
            # scan absorbs packet i of every helper at step i, but a slow
            # helper's step-i result arrives later than a fast helper's
            # step-i+k one, so a send scheduled before decode_t_done can
            # still beat the decodable set already in flight — only sends at
            # or past decode_t_done are provably useless (StepCtx doc).
            tx = jnp.where(
                ctx.decode_done & (tx >= ctx.decode_t_done), jnp.inf, tx)
        return tx

    def finalize(self, outs, aux, cfg, R: int, kk: int, tx_end):
        if not self.decoder_feedback:
            return super().finalize(outs, aux, cfg, R, kk, tx_end)
        return decode_mod.finalize_decode(outs, aux, R, tx_end)

    def summary(self, state) -> dict:
        return {"p_hat": state["p_hat"]}


register("adaptive_rate", factory=AdaptiveRatePolicy)
# Decode-aware variant: a tighter outage window (2 instead of 4 consecutive
# losses) because in decoder-land a send wasted into an outage burns a
# *distinct* coded symbol, not just pacing budget — spamming through a
# whole-cell outage measurably delays the decode (fig_churn cell regime),
# so the policy concedes to the backoff one loss earlier.
register("adaptive_rate_fb",
         factory=lambda: AdaptiveRatePolicy(decoder_feedback=True,
                                            outage_run=2))
