"""Pallas TPU flash attention: causal / sliding-window / softcap / GQA.

Tiled online-softmax (Flash-2 schedule) adapted to the TPU memory
hierarchy: q/k/v tiles stream HBM->VMEM, the running max/denominator and
the fp32 output accumulator live in VMEM scratch across the kv-tile
reduction, and the two matmuls per step hit the MXU with 128-aligned tiles.

Grid (B, Hq, q_tiles, kv_tiles) — kv innermost (reduction).  GQA is handled
in the k/v index_map (kv head = q head // group), so no repeated k/v is
materialized (saves Hq/Hkv x HBM traffic for k/v vs. the naive path).

Block skipping: fully-masked kv tiles (beyond the causal frontier or before
the sliding-window horizon) are skipped with ``pl.when`` — for gemma2-style
window=4096 at 32k context this turns O(T^2) into O(T*W) work per layer.

VMEM working set at (bq, bk, D) = (256, 512, 128), bf16 in / fp32 acc:
q 64KB + k/v 256KB + acc 128KB + m/l 2KB ~ 0.7 MB.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(
    q_ref, k_ref, v_ref, o_ref, acc, m_scr, l_scr,
    *, bq, bk, n_kv, causal, window, softcap, scale, q_offset, tk_valid,
):
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)

    iq = pl.program_id(2)
    q_start = q_offset + iq * bq          # absolute position of first q row
    k_start = ik * bk

    # --- compute-or-skip decision (trace-time where possible) -------------
    # causal frontier: skip if the whole kv tile is in the future.
    # window horizon: skip if the whole kv tile is behind every q row's
    # window (q_start + bq - 1 - (k_start + bk - 1) >= window).
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                    # (bq, bk)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = k_pos < tk_valid
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= (q_pos - k_pos) < window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]                          # (bq, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = corr * l_scr[...] + p.sum(axis=-1, keepdims=True)
        m_scr[...] = m_new
        v = v_ref[0, 0].astype(jnp.float32)          # (bk, D)
        acc[...] = acc[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )

    should_run = k_start < tk_valid
    if causal:
        should_run &= k_start <= q_start + bq - 1
    if window is not None:
        should_run &= (q_start - (k_start + bk - 1)) < window
    pl.when(should_run)(_body)

    @pl.when(ik == n_kv - 1)
    def _finish():
        l = l_scr[...]
        o_ref[0, 0] = jnp.where(l > 0, acc[...] / l, 0.0).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "window", "softcap", "bq", "bk", "q_offset", "tk_valid",
        "interpret",
    ),
)
def flash_attention_pallas(
    q: jnp.ndarray,  # (B, Hq, Tq, D)
    k: jnp.ndarray,  # (B, Hkv, Tk, D)
    v: jnp.ndarray,  # (B, Hkv, Tk, D)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    bq: int = 256,
    bk: int = 512,
    q_offset: int = 0,
    tk_valid: Optional[int] = None,
    interpret: bool = False,
) -> jnp.ndarray:
    B, Hq, Tq, D = q.shape
    Hkv, Tk = k.shape[1], k.shape[2]
    if Hq % Hkv:
        raise ValueError(f"Hq={Hq} not a multiple of Hkv={Hkv}")
    group = Hq // Hkv
    if Tq % bq or Tk % bk:
        raise ValueError(f"Tq={Tq}/Tk={Tk} not divisible by (bq={bq}, bk={bk})")
    nq, nkv = Tq // bq, Tk // bk
    tk_valid = Tk if tk_valid is None else tk_valid
    scale = 1.0 / (D ** 0.5)

    kernel = functools.partial(
        _kernel,
        bq=bq, bk=bk, n_kv=nkv, causal=causal, window=window,
        softcap=softcap, scale=scale, q_offset=q_offset, tk_valid=tk_valid,
    )
    fn = pl.pallas_call(
        kernel,
        grid=(B, Hq, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec(
                (1, 1, bk, D), lambda b, h, iq, ik: (b, h // group, ik, 0)
            ),
            pl.BlockSpec(
                (1, 1, bk, D), lambda b, h, iq, ik: (b, h // group, ik, 0)
            ),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
        name="flash_attention",
    )
    return fn(q, k, v)
