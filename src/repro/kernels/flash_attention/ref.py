"""Pure-jnp oracles for flash attention (causal / sliding-window / softcap /
GQA): a quadratic-memory direct version (small shapes / ground truth) and a
chunked online-softmax version with O(T * chunk) memory (what the CPU
dry-run lowers for long sequences — materializing (T, T) scores at 32k-500k
context would dominate memory_analysis and is exactly what the Pallas
kernel avoids on TPU)."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(
    q: jnp.ndarray,  # (B, Hq, Tq, D)
    k: jnp.ndarray,  # (B, Hkv, Tk, D)
    v: jnp.ndarray,  # (B, Hkv, Tk, D)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    q_offset: int = 0,
    kv_len: Optional[jnp.ndarray] = None,  # (B,) valid kv prefix lengths
) -> jnp.ndarray:
    """Reference attention in fp32. ``q_offset`` is the absolute position of
    q[…, 0, :] (for decode: q_offset = kv_len - Tq). GQA: Hq % Hkv == 0.
    ``window``: attend only to keys with q_pos - k_pos < window (and >= 0
    when causal)."""
    B, Hq, Tq, D = q.shape
    Hkv, Tk = k.shape[1], k.shape[2]
    group = Hq // Hkv
    kr = jnp.repeat(k, group, axis=1)
    vr = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kr.astype(jnp.float32))
    s = s / jnp.sqrt(jnp.asarray(D, jnp.float32))
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = q_offset + jnp.arange(Tq)[:, None]
    k_pos = jnp.arange(Tk)[None, :]
    mask = jnp.ones((Tq, Tk), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    mask = mask[None, None]
    if kv_len is not None:
        mask = mask & (k_pos[None, None] < kv_len[:, None, None, None])
    s = jnp.where(mask, s, NEG_INF)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = jnp.where(mask, p, 0.0)
    denom = p.sum(axis=-1, keepdims=True)
    p = jnp.where(denom > 0, p / denom, 0.0)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vr.astype(jnp.float32))
    return out.astype(q.dtype)


def attention_chunked(
    q: jnp.ndarray,  # (B, Hq, Tq, D)
    k: jnp.ndarray,  # (B, Hkv, Tk, D)
    v: jnp.ndarray,  # (B, Hkv, Tk, D)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    q_offset=0,
    kv_len: Optional[jnp.ndarray] = None,
    chunk: int = 1024,
    unroll: bool = False,
) -> jnp.ndarray:
    """Flash-style chunked attention in pure jnp: lax.scan over kv chunks
    with a running (max, denom, acc) online softmax.  Same semantics as
    :func:`attention_ref`; memory O(B*H*Tq*(D + chunk)).  ``q_offset`` and
    ``kv_len`` may be traced (decode path).  ``unroll`` unrolls the chunk
    scan (dry-run: XLA cost analysis counts rolled loop bodies once)."""
    B, Hq, Tq, D = q.shape
    Hkv, Tk = k.shape[1], k.shape[2]
    group = Hq // Hkv
    if Tk % chunk:
        pad = chunk - Tk % chunk
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        if kv_len is None:
            kv_len = jnp.full((B,), Tk, jnp.int32)
        Tk = Tk + pad
    n_chunks = Tk // chunk
    qf = q.astype(jnp.float32) / jnp.sqrt(jnp.asarray(D, jnp.float32))
    q_pos = q_offset + jnp.arange(Tq)[:, None]                     # (Tq, 1)

    # reshape k/v to (n_chunks, B, Hkv, chunk, D) for scan
    kc = k.reshape(B, Hkv, n_chunks, chunk, D).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(B, Hkv, n_chunks, chunk, D).transpose(2, 0, 1, 3, 4)

    def step(carry, xs):
        m_prev, l_prev, acc = carry
        kj, vj, j = xs
        kj = jnp.repeat(kj.astype(jnp.float32), group, axis=1)     # (B,Hq,c,D)
        vj = jnp.repeat(vj.astype(jnp.float32), group, axis=1)
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, kj)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        k_pos = j * chunk + jnp.arange(chunk)[None, :]             # (1, chunk)
        mask = jnp.ones((Tq, chunk), bool)
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= (q_pos - k_pos) < window
        mask = mask[None, None]
        if kv_len is not None:
            mask = mask & (k_pos[None, None] < kv_len[:, None, None, None])
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.where(mask, jnp.exp(s - m_new[..., None]), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_new = corr * l_prev + p.sum(axis=-1)
        acc = acc * corr[..., None] + jnp.einsum("bhqk,bhkd->bhqd", p, vj)
        return (m_new, l_new, acc), None

    m0 = jnp.full((B, Hq, Tq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hq, Tq), jnp.float32)
    acc0 = jnp.zeros((B, Hq, Tq, D), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, acc0), (kc, vc, jnp.arange(n_chunks)), unroll=unroll
    )
    out = jnp.where(l[..., None] > 0, acc / l[..., None], 0.0)
    return out.astype(q.dtype)
