"""Public attention op: Pallas kernel on TPU, jnp oracle elsewhere.

Pads sequence lengths to block multiples (padding keys are masked via
``tk_valid``; padded q rows are sliced off), picks block sizes that divide
the padded shapes, and exposes the decode case (Tq=1 against a long KV
cache) through the same interface.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .kernel import flash_attention_pallas
from .ref import attention_chunked, attention_ref

# Above this key length the jnp fallback switches to the chunked
# online-softmax path (O(T*chunk) memory instead of O(T^2)).
CHUNKED_THRESHOLD = 2048

# Module-level chunked-scan options (the dry-run sets unroll=True + a large
# chunk so XLA cost analysis sees every chunk body; see launch/dryrun.py).
CHUNK_OPTS = {"chunk": 1024, "unroll": False}


def set_chunk_opts(chunk: int = 1024, unroll: bool = False) -> None:
    CHUNK_OPTS["chunk"] = chunk
    CHUNK_OPTS["unroll"] = unroll


def _pad_to(v: int, m: int) -> int:
    return (v + m - 1) // m * m


def _pick_block(t: int, pref: int) -> int:
    if t >= pref:
        return pref
    # smallest power of two >= t (tiny test shapes)
    b = 1
    while b < t:
        b *= 2
    return b


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "window", "softcap", "q_offset", "use_pallas", "interpret",
        "bq", "bk",
    ),
)
def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    q_offset: int = 0,
    use_pallas: bool = False,
    interpret: bool = False,
    bq: int = 256,
    bk: int = 512,
) -> jnp.ndarray:
    """Attention over (B, H, T, D) tensors; see kernel.py for semantics."""
    if not use_pallas:
        if k.shape[2] > CHUNKED_THRESHOLD:
            return attention_chunked(
                q, k, v, causal=causal, window=window, softcap=softcap,
                q_offset=q_offset, **CHUNK_OPTS,
            )
        return attention_ref(
            q, k, v, causal=causal, window=window, softcap=softcap,
            q_offset=q_offset,
        )
    B, Hq, Tq, D = q.shape
    Tk = k.shape[2]
    bq_eff = _pick_block(Tq, bq)
    bk_eff = _pick_block(Tk, bk)
    tq_p, tk_p = _pad_to(Tq, bq_eff), _pad_to(Tk, bk_eff)
    q_p = jnp.pad(q, ((0, 0), (0, 0), (0, tq_p - Tq), (0, 0)))
    k_p = jnp.pad(k, ((0, 0), (0, 0), (0, tk_p - Tk), (0, 0)))
    v_p = jnp.pad(v, ((0, 0), (0, 0), (0, tk_p - Tk), (0, 0)))
    out = flash_attention_pallas(
        q_p, k_p, v_p,
        causal=causal, window=window, softcap=softcap,
        bq=bq_eff, bk=bk_eff, q_offset=q_offset, tk_valid=Tk,
        interpret=interpret,
    )
    return out[:, :, :Tq]


def attention_flops(
    B: int, Hq: int, Tq: int, Tk: int, D: int,
    causal: bool, window: Optional[int],
) -> float:
    """Useful FLOPs of one attention call (both matmuls), accounting for the
    causal/window sparsity the kernel actually exploits."""
    if window is not None:
        pairs = sum(min(w + 1, q + 1 if causal else Tk)
                    for q, w in ((i, window - 1) for i in range(Tq)))
    elif causal:
        off = Tk - Tq
        pairs = sum(min(off + i + 1, Tk) for i in range(Tq))
    else:
        pairs = Tq * Tk
    return 2.0 * 2.0 * B * Hq * pairs * D
