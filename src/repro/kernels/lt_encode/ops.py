"""Jitted wrapper for the standalone LT-encode kernel (+ jnp fallback)."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ...core.fountain import LTCode
from ..coded_matmul.ref import lt_encode_ref
from .kernel import lt_encode_pallas


def _pad_to(v: int, m: int) -> int:
    return (v + m - 1) // m * m


@functools.partial(jax.jit, static_argnames=("bm", "bc", "use_pallas", "interpret"))
def lt_encode(
    a: jnp.ndarray,
    idx: jnp.ndarray,
    mask: jnp.ndarray,
    *,
    bm: int,
    bc: int = 512,
    use_pallas: bool = False,
    interpret: bool = False,
) -> jnp.ndarray:
    """coded[b] = sum_j mask[b,j] * A[idx[b,j]] over bm-row blocks.

    a: (R*bm, n_cols) -> (C*bm, n_cols).
    """
    if not use_pallas:
        return lt_encode_ref(a, idx, mask, bm)
    n_cols = a.shape[1]
    cp = _pad_to(n_cols, bc)
    a_p = jnp.pad(a, ((0, 0), (0, cp - n_cols)))
    out = lt_encode_pallas(a_p, idx, mask, bm=bm, bc=bc, interpret=interpret)
    return out[:, :n_cols]


def lt_encode_code(a: jnp.ndarray, code: LTCode, *, bm: Optional[int] = None, **kw):
    if bm is None:
        if a.shape[0] % code.R:
            raise ValueError(f"a rows {a.shape[0]} not divisible by R={code.R}")
        bm = a.shape[0] // code.R
    return lt_encode(a, jnp.asarray(code.idx), jnp.asarray(code.weights), bm=bm, **kw)
