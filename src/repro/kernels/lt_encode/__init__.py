from .kernel import lt_encode_pallas  # noqa: F401
from .ops import lt_encode, lt_encode_code  # noqa: F401
