"""Pallas TPU kernel: standalone LT encode (gather + masked accumulate).

Used where the *encoded object itself* is the output — e.g. building parity
gradient blocks for coded gradient aggregation — rather than an input to a
matmul (use kernels.coded_matmul for the fused case).

Grid (C, col_tiles, d_max), j innermost; each step DMA's one source tile
A[idx[b, j]] HBM->VMEM and adds it into an fp32 accumulator; the tile is
written once per (b, c).  Pure VPU + DMA (no MXU): this kernel is memory
bound by design, so tiles are sized large (bm x 512) to keep DMA efficiency
high; VMEM working set = (2 + 4 + 2) B * bm * bc ~ 1 MB at (256, 512).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(idx_ref, mask_ref, a_ref, o_ref, acc, *, d_max):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    b = pl.program_id(0)
    m = mask_ref[b, j].astype(jnp.float32)
    acc[...] += a_ref[...].astype(jnp.float32) * m

    @pl.when(j == d_max - 1)
    def _write():
        o_ref[...] = acc[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bc", "interpret"))
def lt_encode_pallas(
    a: jnp.ndarray,     # (R * bm, n_cols)
    idx: jnp.ndarray,   # (C, d_max) int32
    mask: jnp.ndarray,  # (C, d_max)
    *,
    bm: int,
    bc: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    n_cols = a.shape[1]
    C, d_max = idx.shape
    if a.shape[0] % bm or n_cols % bc:
        raise ValueError(f"a {a.shape} not divisible by (bm={bm}, bc={bc})")
    nc = n_cols // bc
    grid = (C, nc, d_max)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec(
                (bm, bc), lambda b, c, j, idx_ref, mask_ref: (idx_ref[b, j], c)
            ),
        ],
        out_specs=pl.BlockSpec(
            (bm, bc), lambda b, c, j, idx_ref, mask_ref: (b, c)
        ),
        scratch_shapes=[pltpu.VMEM((bm, bc), jnp.float32)],
    )
    fn = pl.pallas_call(
        functools.partial(_kernel, d_max=d_max),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((C * bm, n_cols), a.dtype),
        interpret=interpret,
        name="lt_encode",
    )
    return fn(idx.astype(jnp.int32), mask.astype(jnp.float32), a)
