"""Pure-jnp reference for the round-based LT payload decode.

The offline :func:`repro.core.fountain.apply_decode_plan` walks the peeling
schedule one source at a time (an O(T)-step ``lax.scan``).  The kernel path
instead executes the :func:`repro.core.fountain.plan_rounds` levelization:
every source of a round is recovered by one batched masked gather +
subtract, so the device-side critical path is the dependency depth
(typically O(log R)) rather than T.  This module is the jnp oracle the
Pallas kernel is pinned against — and the dispatch fallback off-TPU.
"""

from __future__ import annotations

import jax.numpy as jnp

from ...core import fountain


def peel_round_ref(src, coded, rnd, *, bm: int):
    """Apply one :class:`~repro.core.fountain.PlanRound` to the source
    buffer.

    src:   (R, bm, n_cols) partially recovered source blocks.
    coded: (n_rx, bm, n_cols) received coded blocks.
    Returns the (S, bm, n_cols) newly recovered blocks for ``rnd.src``.
    """
    gathered = src[jnp.asarray(rnd.nbr_idx)]          # (S, d_max, bm, cols)
    w = jnp.asarray(rnd.nbr_coef).astype(src.dtype)[:, :, None, None]
    piv = jnp.asarray(rnd.pivot).astype(src.dtype)[:, None, None]
    return (coded[jnp.asarray(rnd.coded)] - (gathered * w).sum(axis=1)) / piv


def lt_decode_ref(coded_rx: jnp.ndarray, plan: fountain.DecodePlan,
                  *, bm: int) -> jnp.ndarray:
    """Round-based peeling decode: (n_rx * bm, n_cols) -> (R * bm, n_cols).

    Bit-compatible with the Pallas kernel path (same round schedule, same
    accumulation order) and numerically equal to
    :func:`fountain.apply_decode_plan` up to fp addition order.
    """
    n_cols = coded_rx.shape[1]
    n_rx = coded_rx.shape[0] // bm
    coded = coded_rx.reshape(n_rx, bm, n_cols)
    src = jnp.zeros((plan.R, bm, n_cols), coded_rx.dtype)
    if plan.direct_src.size:
        dcoef = jnp.asarray(plan.direct_coef).astype(src.dtype)[:, None, None]
        src = src.at[jnp.asarray(plan.direct_src)].set(
            coded[jnp.asarray(plan.direct_coded)] / dcoef
        )
    for rnd in fountain.plan_rounds(plan):
        vals = peel_round_ref(src, coded, rnd, bm=bm)
        src = src.at[jnp.asarray(rnd.src)].set(vals)
    return src.reshape(plan.R * bm, n_cols)
