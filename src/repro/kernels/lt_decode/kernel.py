"""Pallas TPU kernel: one LT peeling round (masked gather + subtract).

Mirror image of ``kernels/lt_encode``: where encode accumulates
``sum_j mask * A[idx[b, j]]``, decode *starts* from the received coded block
and subtracts the already-recovered neighbours, then scales by the pivot
coefficient:

    out[s] = (coded[cpos[s]] - sum_j w[s, j] * src[idx[s, j]]) / pivot[s]

Grid (S, col_tiles, d_max), j innermost.  j == 0 initializes the fp32
accumulator with the coded tile (its index map is constant in j, so Pallas
keeps the block resident across the inner iterations — one DMA per (s, c)),
each j subtracts one neighbour tile, and the tile is written once scaled by
``inv_pivot``.  Pure VPU + DMA (no MXU), memory bound by design — tiles are
sized large (bm x 512) like lt_encode so DMA efficiency stays high.

The round schedule (which sources are independent) comes from
:func:`repro.core.fountain.plan_rounds`; one ``pallas_call`` executes one
round, so the device-side critical path is the dependency depth of the
peeling, not its O(R) step count.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(cpos_ref, idx_ref, w_ref, invp_ref, coded_ref, src_ref, o_ref,
            acc, *, d_max):
    s = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc[...] = coded_ref[...].astype(jnp.float32)

    acc[...] -= src_ref[...].astype(jnp.float32) * w_ref[s, j]

    @pl.when(j == d_max - 1)
    def _write():
        o_ref[...] = (acc[...] * invp_ref[s]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bc", "interpret"))
def lt_decode_round_pallas(
    coded: jnp.ndarray,     # (n_rx * bm, n_cols) received coded blocks
    src: jnp.ndarray,       # (R * bm, n_cols) partially recovered sources
    cpos: jnp.ndarray,      # (S,) int32 coded-block position per source
    idx: jnp.ndarray,       # (S, d_max) int32 neighbour source blocks
    w: jnp.ndarray,         # (S, d_max) float32 neighbour coefficients (0 pad)
    inv_pivot: jnp.ndarray,  # (S,) float32 1/pivot
    *,
    bm: int,
    bc: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """One peel round: returns the (S * bm, n_cols) newly recovered blocks."""
    n_cols = coded.shape[1]
    S, d_max = idx.shape
    if coded.shape[0] % bm or src.shape[0] % bm or n_cols % bc:
        raise ValueError(
            f"coded {coded.shape} / src {src.shape} not divisible by "
            f"(bm={bm}, bc={bc})"
        )
    grid = (S, n_cols // bc, d_max)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=grid,
        in_specs=[
            pl.BlockSpec(
                (bm, bc),
                lambda s, c, j, cpos_ref, idx_ref, w_ref, invp_ref:
                    (cpos_ref[s], c),
            ),
            pl.BlockSpec(
                (bm, bc),
                lambda s, c, j, cpos_ref, idx_ref, w_ref, invp_ref:
                    (idx_ref[s, j], c),
            ),
        ],
        out_specs=pl.BlockSpec(
            (bm, bc),
            lambda s, c, j, cpos_ref, idx_ref, w_ref, invp_ref: (s, c),
        ),
        scratch_shapes=[pltpu.VMEM((bm, bc), jnp.float32)],
    )
    fn = pl.pallas_call(
        functools.partial(_kernel, d_max=d_max),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((S * bm, n_cols), coded.dtype),
        interpret=interpret,
        name="lt_decode",
    )
    return fn(cpos.astype(jnp.int32), idx.astype(jnp.int32),
              w.astype(jnp.float32), inv_pivot.astype(jnp.float32),
              coded, src)
