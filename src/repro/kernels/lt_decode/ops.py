"""Dispatch wrapper for the LT payload decode (+ jnp fallback).

Mirrors ``kernels/lt_encode/ops.py``: ``lt_decode`` takes the received
coded blocks and a peeling :class:`~repro.core.fountain.DecodePlan`,
executes the direct (systematic) fills, then one
:func:`~.kernel.lt_decode_round_pallas` call per
:func:`~repro.core.fountain.plan_rounds` level — or the pure-jnp
``ref.lt_decode_ref`` path when ``use_pallas=False`` (CPU/GPU, tests).
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

from ...core import fountain
from .kernel import lt_decode_round_pallas
from .ref import lt_decode_ref


def _pad_to(v: int, m: int) -> int:
    return (v + m - 1) // m * m


def lt_decode(
    coded_rx: jnp.ndarray,
    plan: fountain.DecodePlan,
    *,
    bm: int,
    bc: int = 512,
    use_pallas: bool = False,
    interpret: bool = False,
) -> jnp.ndarray:
    """Recover the source rows from received coded rows via peeling.

    coded_rx: (n_rx * bm, n_cols) — the received coded blocks, in the order
    of the ``received_ids`` the plan was built from.  Returns
    (R * bm, n_cols).
    """
    if coded_rx.shape[0] % bm:
        raise ValueError(
            f"coded_rx rows {coded_rx.shape[0]} not divisible by bm={bm}")
    if not use_pallas:
        return lt_decode_ref(coded_rx, plan, bm=bm)
    n_cols = coded_rx.shape[1]
    cp = _pad_to(n_cols, bc)
    coded_p = jnp.pad(coded_rx, ((0, 0), (0, cp - n_cols)))
    src = jnp.zeros((plan.R * bm, cp), coded_rx.dtype)
    if plan.direct_src.size:
        # Degree-1 receipts are plain scaled copies — a gather, not a kernel.
        n_rx = coded_p.shape[0] // bm
        c3 = coded_p.reshape(n_rx, bm, cp)
        dcoef = jnp.asarray(plan.direct_coef).astype(src.dtype)[:, None, None]
        src = src.reshape(plan.R, bm, cp).at[
            jnp.asarray(plan.direct_src)
        ].set(c3[jnp.asarray(plan.direct_coded)] / dcoef).reshape(-1, cp)
    for rnd in fountain.plan_rounds(plan):
        vals = lt_decode_round_pallas(
            coded_p, src,
            jnp.asarray(rnd.coded), jnp.asarray(rnd.nbr_idx),
            jnp.asarray(rnd.nbr_coef),
            jnp.asarray(1.0 / rnd.pivot, dtype=jnp.float32),
            bm=bm, bc=bc, interpret=interpret,
        )
        src = src.reshape(plan.R, bm, cp).at[jnp.asarray(rnd.src)].set(
            vals.reshape(rnd.size, bm, cp)
        ).reshape(-1, cp)
    return src[:, :n_cols]


def lt_decode_code(
    coded_rx: jnp.ndarray,
    code: fountain.LTCode,
    received_ids: np.ndarray,
    *,
    bm: Optional[int] = None,
    **kw,
) -> jnp.ndarray:
    """Plan-and-decode convenience: peel ``received_ids`` of ``code`` and
    apply.  Raises when peeling stalls (caller falls back to
    :func:`fountain.decode`'s dense solve)."""
    plan = fountain.peel_decode_plan(code, received_ids)
    if plan is None:
        raise ValueError(
            "peeling stalled on the received set; use fountain.decode for "
            "the dense fallback"
        )
    if bm is None:
        n_rx = len(np.asarray(received_ids))
        if coded_rx.shape[0] % n_rx:
            raise ValueError(
                f"coded_rx rows {coded_rx.shape[0]} not divisible by "
                f"n_rx={n_rx}")
        bm = coded_rx.shape[0] // n_rx
    return lt_decode(coded_rx, plan, bm=bm, **kw)
