from .kernel import lt_decode_round_pallas  # noqa: F401
from .ops import lt_decode, lt_decode_code  # noqa: F401
from .ref import lt_decode_ref, peel_round_ref  # noqa: F401
