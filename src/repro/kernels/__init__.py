"""Pallas TPU kernels for the compute hot spots.

- coded_matmul: fused LT-encode + block matmul — the paper's own hot spot
  (helpers computing fountain-coded sub-matrix products) adapted to the MXU.
- lt_encode: standalone gather-accumulate encoder (coded gradient parities).
- flash_attention: tiled online-softmax attention (causal / sliding-window /
  logit-softcap / GQA) — the serving & training hot spot of the assigned
  architectures.

All kernels are TPU-targeted (pl.pallas_call + BlockSpec VMEM tiling) and
validated on CPU with interpret=True against the pure-jnp oracles in each
package's ref.py.  The jnp fallbacks (ops.py, use_pallas=False) are what the
CPU dry-run lowers.
"""

from . import coded_matmul, flash_attention, lt_encode  # noqa: F401
