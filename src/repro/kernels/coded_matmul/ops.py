"""Jitted public wrapper for the fused coded matmul.

Handles padding to block multiples, the jnp fallback (used on CPU and in the
dry-run lowering), and LTCode plumbing.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax.numpy as jnp
import numpy as np

from ...core.fountain import LTCode
from .kernel import coded_matmul_pallas
from .ref import coded_matmul_ref


def _pad_to(v: int, m: int) -> int:
    return (v + m - 1) // m * m


@functools.partial(
    __import__("jax").jit,
    static_argnames=("bm", "bk", "bn", "use_pallas", "interpret"),
)
def coded_matmul(
    a: jnp.ndarray,
    x: jnp.ndarray,
    idx: jnp.ndarray,
    mask: jnp.ndarray,
    *,
    bm: int,
    bk: int = 256,
    bn: int = 256,
    use_pallas: bool = False,
    interpret: bool = False,
) -> jnp.ndarray:
    """V[b*bm:(b+1)*bm] = (sum_j mask[b,j] A[idx[b,j]]) @ x for coded block b.

    a: (R*bm, k_dim); x: (k_dim, n_dim); idx/mask: (C, d_max).
    Returns (C*bm, n_dim).
    """
    if not use_pallas:
        return coded_matmul_ref(a, x, idx, mask, bm)
    k_dim, n_dim = x.shape
    kp, np_ = _pad_to(k_dim, bk), _pad_to(n_dim, bn)
    a_p = jnp.pad(a, ((0, 0), (0, kp - k_dim)))
    x_p = jnp.pad(x, ((0, kp - k_dim), (0, np_ - n_dim)))
    out = coded_matmul_pallas(
        a_p, x_p, idx, mask, bm=bm, bk=bk, bn=bn, interpret=interpret
    )
    return out[:, :n_dim]


def coded_matmul_code(
    a: jnp.ndarray,
    x: jnp.ndarray,
    code: LTCode,
    *,
    bm: Optional[int] = None,
    **kw,
) -> jnp.ndarray:
    """Convenience: drive the kernel from an LTCode. ``a`` rows must split
    into ``code.R`` equal blocks (bm inferred when not given)."""
    if bm is None:
        if a.shape[0] % code.R:
            raise ValueError(f"a rows {a.shape[0]} not divisible by R={code.R}")
        bm = a.shape[0] // code.R
    return coded_matmul(
        a, x, jnp.asarray(code.idx), jnp.asarray(code.weights), bm=bm, **kw
    )


def flops(R: int, K: int, bm: int, k_dim: int, n_dim: int, d_mean: float) -> dict:
    """Roofline terms for one fused coded matmul (per §Roofline).

    Returns flops of the MXU matmul part, VPU encode adds, and HBM bytes
    moved (bf16), for napkin math in benchmarks/kernel_bench.py.
    """
    C = R + K
    matmul = 2.0 * C * bm * k_dim * n_dim
    encode_adds = d_mean * C * bm * k_dim
    bytes_fused = 2.0 * (d_mean * C * bm * k_dim + k_dim * n_dim + C * bm * n_dim)
    bytes_unfused = bytes_fused + 2.0 * 2.0 * C * bm * k_dim  # write+read A_enc
    return dict(
        matmul_flops=matmul,
        encode_flops=encode_adds,
        hbm_bytes_fused=bytes_fused,
        hbm_bytes_unfused=bytes_unfused,
    )
