"""Pure-jnp oracle for the fused LT-encode + block-matmul kernel.

Semantics: the source matrix ``a`` is ``R`` row-blocks of ``bm`` rows.  For
each coded block ``b`` (of ``C = R + K`` total),

    A_enc[b] = sum_j mask[b, j] * A[idx[b, j]]          (LT encode)
    V[b]     = A_enc[b] @ x                             (block matmul)

Returns V as a ``(C * bm, n)`` matrix.  This is exactly
``fountain.encode`` followed by a dense matmul; the Pallas kernel fuses the
two so the encoded ``A`` never round-trips through HBM.
"""

from __future__ import annotations

import jax.numpy as jnp


def coded_matmul_ref(
    a: jnp.ndarray,      # (R * bm, k_dim)
    x: jnp.ndarray,      # (k_dim, n_dim)
    idx: jnp.ndarray,    # (C, d_max) int32 — source-block neighbours
    mask: jnp.ndarray,   # (C, d_max) bool/float — neighbour validity
    bm: int,
) -> jnp.ndarray:
    r_blocks = a.shape[0] // bm
    if a.shape[0] != r_blocks * bm:
        raise ValueError(f"a rows {a.shape[0]} not divisible by bm={bm}")
    blocks = a.reshape(r_blocks, bm, a.shape[1])
    gathered = jnp.take(blocks, idx, axis=0)            # (C, d_max, bm, k)
    m = mask.astype(a.dtype)[:, :, None, None]
    enc = (gathered * m).sum(axis=1)                    # (C, bm, k)
    out = jnp.einsum(
        "cbk,kn->cbn", enc.astype(jnp.float32), x.astype(jnp.float32)
    )
    return out.reshape(-1, x.shape[1]).astype(x.dtype)


def lt_encode_ref(
    a: jnp.ndarray,      # (R * bm, n_cols)
    idx: jnp.ndarray,    # (C, d_max)
    mask: jnp.ndarray,   # (C, d_max)
    bm: int,
) -> jnp.ndarray:
    """Encode-only oracle: returns (C * bm, n_cols)."""
    r_blocks = a.shape[0] // bm
    blocks = a.reshape(r_blocks, bm, a.shape[1])
    gathered = jnp.take(blocks, idx, axis=0)
    m = mask.astype(a.dtype)[:, :, None, None]
    enc = (gathered * m).sum(axis=1)
    return enc.reshape(-1, a.shape[1])
