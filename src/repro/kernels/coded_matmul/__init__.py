from .kernel import coded_matmul_pallas  # noqa: F401
from .ops import coded_matmul, coded_matmul_code  # noqa: F401
from .ref import coded_matmul_ref, lt_encode_ref  # noqa: F401
