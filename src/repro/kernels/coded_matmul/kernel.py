"""Pallas TPU kernel: fused LT-encode + block matmul (the paper's hot spot).

The paper's helpers compute ``p_{n,i} @ x`` where ``p`` is a fountain-coded
packet.  On TPU, the coded unit is an MXU-aligned row-block and the encode
(a sparse ±1 combination of source blocks) is fused into the matmul:

  for each coded block b, output tile n, reduction tile k:
      acc_a  = sum_j mask[b,j] * A[idx[b,j], k-tile]     (VPU adds, VMEM)
      acc_o += acc_a @ X[k-tile, n-tile]                 (MXU)

The gather over ``idx`` uses scalar prefetch: the neighbour table drives the
``A`` BlockSpec index_map, so each A tile is DMA'd HBM->VMEM exactly once
per (b, k, j) and the *encoded* matrix never materializes in HBM.  Vs.
encode-then-matmul this saves a full HBM round trip of the coded A
(write C*bm*K + read C*bm*K bytes).

Grid: (C, n_tiles, k_tiles, d_max) — j innermost so the fp32 VMEM
accumulators live across the encode reduction; k next so output tiles
accumulate across the matmul reduction.

VMEM working set per step: A tile (bm, bk) + X tile (bk, bn) + acc_a
(bm, bk) f32 + acc_o (bm, bn) f32 + out tile — with the default
bm=bk=bn=256 and bf16 inputs that is 256*256*(2+2+4+4+2) B ~ 0.9 MB, well
inside the ~16 MB v5e VMEM budget; tiles are 128-aligned for the MXU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(idx_ref, mask_ref, a_ref, x_ref, o_ref, acc_a, acc_o, *, d_max, nk):
    j = pl.program_id(3)
    k = pl.program_id(2)

    @pl.when(j == 0)
    def _init_acc_a():
        acc_a[...] = jnp.zeros_like(acc_a)

    @pl.when((j == 0) & (k == 0))
    def _init_acc_o():
        acc_o[...] = jnp.zeros_like(acc_o)

    b = pl.program_id(0)
    m = mask_ref[b, j].astype(jnp.float32)
    acc_a[...] += a_ref[...].astype(jnp.float32) * m

    @pl.when(j == d_max - 1)
    def _matmul():
        acc_o[...] += jax.lax.dot_general(
            acc_a[...],
            x_ref[...].astype(jnp.float32),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

        @pl.when(k == nk - 1)
        def _write():
            o_ref[...] = acc_o[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("bm", "bk", "bn", "interpret", "out_dtype"),
)
def coded_matmul_pallas(
    a: jnp.ndarray,     # (R * bm, k_dim)
    x: jnp.ndarray,     # (k_dim, n_dim)
    idx: jnp.ndarray,   # (C, d_max) int32
    mask: jnp.ndarray,  # (C, d_max) any dtype; nonzero = valid
    *,
    bm: int,
    bk: int,
    bn: int,
    interpret: bool = False,
    out_dtype=None,
) -> jnp.ndarray:
    k_dim, n_dim = x.shape
    C, d_max = idx.shape
    if a.shape[1] != k_dim:
        raise ValueError(f"a cols {a.shape[1]} != x rows {k_dim}")
    if k_dim % bk or n_dim % bn or a.shape[0] % bm:
        raise ValueError(
            f"shapes (a={a.shape}, x={x.shape}) not divisible by "
            f"blocks (bm={bm}, bk={bk}, bn={bn}); pad in ops.py"
        )
    nk, nn = k_dim // bk, n_dim // bn
    out_dtype = out_dtype or x.dtype

    grid = (C, nn, nk, d_max)
    kernel = functools.partial(_kernel, d_max=d_max, nk=nk)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec(  # A: gather row-block idx[b, j], k-tile k
                (bm, bk),
                lambda b, n, k, j, idx_ref, mask_ref: (idx_ref[b, j], k),
            ),
            pl.BlockSpec(  # X: (k, n) tile
                (bk, bn),
                lambda b, n, k, j, idx_ref, mask_ref: (k, n),
            ),
        ],
        out_specs=pl.BlockSpec(
            (bm, bn), lambda b, n, k, j, idx_ref, mask_ref: (b, n)
        ),
        scratch_shapes=[
            pltpu.VMEM((bm, bk), jnp.float32),
            pltpu.VMEM((bm, bn), jnp.float32),
        ],
    )
    fn = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((C * bm, n_dim), out_dtype),
        interpret=interpret,
        name="coded_matmul",
    )
    return fn(idx.astype(jnp.int32), mask.astype(jnp.float32), a, x)
