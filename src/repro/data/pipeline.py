"""Deterministic synthetic token pipeline with background prefetch.

Every batch is a pure function of (seed, step) — restart-safe (resuming at
step k reproduces the exact stream, so checkpoint/restart does not skew
data order) and host-shardable (each host materializes only its slice).

The stream is a Zipf-ish unigram mixture with short-range correlations, so
cross-entropy is learnable (tests assert loss decreases) without any
external data dependency.
"""

from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import jax
import numpy as np


class SyntheticLM:
    """Micro-shaped batches: tokens/labels (n_micro, mb, T) int32."""

    def __init__(
        self,
        vocab: int,
        seq_len: int,
        global_batch: int,
        n_micro: int = 1,
        seed: int = 0,
        zipf_a: float = 1.2,
        copy_period: int = 8,
    ):
        assert global_batch % n_micro == 0
        self.vocab = vocab
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.n_micro = n_micro
        self.seed = seed
        self.copy_period = copy_period
        # fixed unigram distribution (deterministic in seed)
        rng = np.random.default_rng(seed)
        w = rng.zipf(zipf_a, size=vocab).astype(np.float64)
        self.probs = w / w.sum()

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        shape = (self.n_micro, self.global_batch // self.n_micro, self.seq_len + 1)
        toks = rng.choice(self.vocab, size=shape, p=self.probs).astype(np.int32)
        # short-range structure: every copy_period-th token repeats its
        # predecessor (a learnable bigram signal)
        idx = np.arange(1, shape[-1], self.copy_period)
        toks[..., idx] = toks[..., idx - 1]
        return {
            "tokens": toks[..., :-1],
            "labels": toks[..., 1:],
        }

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch + optional device_put with a sharding."""

    def __init__(self, source, start_step: int = 0, depth: int = 2,
                 shardings: Optional[Dict] = None):
        self.source = source
        self.shardings = shardings
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            b = self.source.batch(step)
            if self.shardings is not None:
                b = {
                    k: jax.device_put(v, self.shardings[k]) if k in self.shardings
                    else v
                    for k, v in b.items()
                }
            try:
                self.q.put((step, b), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    def next(self):
        while True:
            try:
                return self.q.get(timeout=1.0)
            except queue.Empty:
                if self._stop.is_set():
                    raise RuntimeError("prefetcher stopped")

    def stop(self):
        self._stop.set()
