from .pipeline import Prefetcher, SyntheticLM  # noqa: F401
