"""Dense MLP blocks: SwiGLU / GeGLU / plain GELU."""

from __future__ import annotations

from typing import Any, Dict

import jax.numpy as jnp

from .common import ACT, ParamBuilder
from .config import ModelConfig


def init_mlp(pb: ParamBuilder, cfg: ModelConfig) -> Dict[str, Any]:
    d, f = cfg.d_model, cfg.d_ff
    if cfg.mlp in ("swiglu", "geglu"):
        return {
            "w_gate": pb.fan_in((d, f), ("embed", "ff"), fan_axis=0),
            "w_up": pb.fan_in((d, f), ("embed", "ff"), fan_axis=0),
            "w_down": pb.fan_in((f, d), ("ff", "embed"), fan_axis=0),
        }
    return {
        "w_up": pb.fan_in((d, f), ("embed", "ff"), fan_axis=0),
        "w_down": pb.fan_in((f, d), ("ff", "embed"), fan_axis=0),
    }


def mlp(params: Dict[str, Any], x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    if cfg.mlp in ("swiglu", "geglu"):
        act = ACT["silu" if cfg.mlp == "swiglu" else "gelu"]
        g = act(x @ params["w_gate"].astype(x.dtype))
        u = x @ params["w_up"].astype(x.dtype)
        return (g * u) @ params["w_down"].astype(x.dtype)
    h = ACT["gelu"](x @ params["w_up"].astype(x.dtype))
    return h @ params["w_down"].astype(x.dtype)
