"""Mixture-of-Experts block with sort-based (dropping, capacity-bounded)
token dispatch.

Dispatch strategy (static shapes, EP-shardable, no (S, E, C) one-hot blowup):

  1. router scores -> top_k expert ids + weights per token;
  2. flatten the S*k assignments, sort by expert id;
  3. each expert e gets a static (C,) slot table: slot (e, c) holds the c-th
     token assigned to e (or -1 beyond its count — capacity drop, standard
     GShard semantics);
  4. gather -> (E, C, d), batched expert FFN einsum, scatter-add back with
     router weights.

The expert tensors carry the 'experts' logical axis, which the sharding
rules map to the 'model' mesh axis (expert parallelism); GSPMD turns the
gather/scatter into all-to-all collectives over that axis.  Parity blocks
for coded gradient aggregation stay *within* expert shards (DESIGN.md §4).
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from .common import ACT, ParamBuilder
from .config import ModelConfig

# §Perf knobs (set by the dry-run/perf harness):
#   constrain — pin dispatched intermediates to EP sharding (GSPMD hint;
#     measured a no-op on qwen3, kept for the record — §Perf A1/A4);
#   a2a_mesh — use the explicit shard_map formulation in moe_a2a.py (the
#     measured fix for the dispatch-collective blowup — §Perf A5).
# Off by default: the baseline records the unconstrained partitioner.
MOE_OPTS = {"constrain": False, "a2a_mesh": None}


def set_moe_opts(constrain: bool = False, a2a_mesh=None) -> None:
    MOE_OPTS["constrain"] = constrain
    MOE_OPTS["a2a_mesh"] = a2a_mesh


def _constrain(x, spec):
    if not MOE_OPTS["constrain"]:
        return x
    import jax
    from jax.sharding import PartitionSpec as P

    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except Exception:
        return x  # no mesh in context (single-device tests)


def init_moe(pb: ParamBuilder, cfg: ModelConfig) -> Dict[str, Any]:
    assert cfg.moe is not None
    d, m = cfg.d_model, cfg.moe
    f = m.d_ff_expert
    p = {
        "router": pb.normal((d, m.n_experts), ("embed", "experts"), stddev=d ** -0.5),
        "w_gate": pb.fan_in((m.n_experts, d, f), ("experts", "embed", "ff"), fan_axis=1),
        "w_up": pb.fan_in((m.n_experts, d, f), ("experts", "embed", "ff"), fan_axis=1),
        "w_down": pb.fan_in((m.n_experts, f, d), ("experts", "ff", "embed"), fan_axis=1),
    }
    if m.n_shared:
        p["shared_gate"] = pb.fan_in((d, m.n_shared * f), ("embed", "ff"), fan_axis=0)
        p["shared_up"] = pb.fan_in((d, m.n_shared * f), ("embed", "ff"), fan_axis=0)
        p["shared_down"] = pb.fan_in((m.n_shared * f, d), ("ff", "embed"), fan_axis=0)
    return p


def _capacity(s_tokens: int, m) -> int:
    c = int(s_tokens * m.top_k * m.capacity_factor / m.n_experts) + 1
    return max(c, m.top_k)


def moe_block(
    params: Dict[str, Any], x: jnp.ndarray, cfg: ModelConfig
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, T, D) -> (out, aux_loss). Aux = load-balance loss (Switch)."""
    if MOE_OPTS["a2a_mesh"] is not None:
        from .moe_a2a import moe_block_a2a

        return moe_block_a2a(params, x, cfg, MOE_OPTS["a2a_mesh"])
    m = cfg.moe
    B, T, D = x.shape
    S = B * T
    xf = x.reshape(S, D)
    logits = (xf @ params["router"].astype(x.dtype)).astype(jnp.float32)  # (S, E)
    if m.router_softcap:
        logits = m.router_softcap * jnp.tanh(logits / m.router_softcap)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, m.top_k)                # (S, k)
    top_w = top_w / jnp.clip(top_w.sum(-1, keepdims=True), 1e-9)

    # ---- sort-based dispatch -------------------------------------------
    C = _capacity(S, m)
    flat_e = top_e.reshape(-1)                                   # (S*k,)
    flat_w = top_w.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(S), m.top_k)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    sorted_tok = flat_tok[order]
    sorted_w = flat_w[order]
    counts = jnp.bincount(flat_e, length=m.n_experts)            # (E,)
    offsets = jnp.concatenate([jnp.zeros(1, counts.dtype), jnp.cumsum(counts)[:-1]])
    # slot (e, c) -> index into sorted arrays, masked past each count
    slot_idx = offsets[:, None] + jnp.arange(C)[None, :]         # (E, C)
    slot_valid = jnp.arange(C)[None, :] < counts[:, None]
    slot_idx = jnp.clip(slot_idx, 0, S * m.top_k - 1)
    tok_at_slot = jnp.where(slot_valid, sorted_tok[slot_idx], 0)
    w_at_slot = jnp.where(slot_valid, sorted_w[slot_idx], 0.0)

    xd = xf[tok_at_slot]                                         # (E, C, D)
    xd = xd * slot_valid[..., None].astype(xd.dtype)
    xd = _constrain(xd, ("model", None, None))      # tokens move to experts
    act = ACT["silu"]
    g = act(jnp.einsum("ecd,edf->ecf", xd, params["w_gate"].astype(xd.dtype)))
    u = jnp.einsum("ecd,edf->ecf", xd, params["w_up"].astype(xd.dtype))
    g = _constrain(g, ("model", None, "data"))      # ff stays data-sharded
    u = _constrain(u, ("model", None, "data"))
    y = jnp.einsum("ecf,efd->ecd", g * u, params["w_down"].astype(xd.dtype))
    y = _constrain(y, ("model", None, None))        # psum over data inside
    y = y * w_at_slot[..., None].astype(y.dtype)

    out = jax.ops.segment_sum(
        y.reshape(-1, D).astype(x.dtype), tok_at_slot.reshape(-1),
        num_segments=S,
    ).astype(x.dtype)
    # data-sharded combine output: lets the partitioner reduce-scatter the
    # cross-(model,data) combine instead of all-reducing the full buffer
    out = _constrain(out, ("data", None))

    if m.n_shared:
        gs = act(xf @ params["shared_gate"].astype(x.dtype))
        us = xf @ params["shared_up"].astype(x.dtype)
        out = out + (gs * us) @ params["shared_down"].astype(x.dtype)

    # Switch-style load-balance auxiliary loss.
    me = probs.mean(axis=0)                                      # (E,)
    ce = jnp.bincount(flat_e, length=m.n_experts) / (S * m.top_k)
    aux = m.n_experts * jnp.sum(me * ce)
    return out.reshape(B, T, D), aux.astype(jnp.float32)
