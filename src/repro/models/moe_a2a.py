"""Explicit shard_map MoE: the fix for the dispatch-collective blowup.

GSPMD realizes the gather-based token dispatch of ``moe.moe_block`` as fp32
full-(E, C, D)-buffer all-reduces over the data axis (~20 GB/layer/micro on
qwen3 — §Perf cell A). The structure the partitioner misses: within one
data shard, activations are *replicated over the model axis*, so device
(d, m) already holds every token its local experts E_m need. The explicit
formulation per device is therefore

  1. all-gather the FSDP (ff->data) slices of the *local* experts' weights
     over 'data'    (~0.9 GB/group on qwen3 — unavoidable under FSDP),
  2. dispatch local tokens to local experts (sort/capacity — no comms),
  3. full-ff expert FFN,
  4. scatter-add back to token positions,
  5. psum over 'model' (each token's top-k experts live across model
     shards): (S_loc, D) bf16 ~ 67 MB.

Net wire ~1 GB/group/micro vs ~20 GB for the GSPMD path (~20x).
Capacity semantics differ slightly from the global version: the capacity
bound applies per data shard (standard practice in EP systems).
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .common import ACT
from .config import ModelConfig


def moe_block_a2a(
    params: Dict[str, Any], x: jnp.ndarray, cfg: ModelConfig, mesh,
    data_axis: str = "data", model_axis: str = "model",
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Drop-in for moe.moe_block over a ('data','model') mesh."""
    m = cfg.moe
    names = dict(zip(mesh.axis_names, mesh.devices.shape))
    model_n = names.get(model_axis, 1)
    data_n = names.get(data_axis, 1)
    assert m.n_experts % model_n == 0, (m.n_experts, model_n)
    e_loc = m.n_experts // model_n

    def shard_fn(router, wg, wu, wd, sg, su, sd, x_loc):
        # x_loc: (B_loc, T, D); wg/wu: (E_loc, D, F_loc); wd: (E_loc, F_loc, D)
        B_loc, T, D = x_loc.shape
        S = B_loc * T
        xf = x_loc.reshape(S, D)
        logits = (xf @ router.astype(x_loc.dtype)).astype(jnp.float32)
        if m.router_softcap:
            logits = m.router_softcap * jnp.tanh(logits / m.router_softcap)
        probs = jax.nn.softmax(logits, axis=-1)
        top_w, top_e = jax.lax.top_k(probs, m.top_k)
        top_w = top_w / jnp.clip(top_w.sum(-1, keepdims=True), 1e-9)

        # FSDP re-assembly of this model-shard's experts (tiled over data)
        if data_n > 1:
            wg_f = jax.lax.all_gather(wg, data_axis, axis=2, tiled=True)
            wu_f = jax.lax.all_gather(wu, data_axis, axis=2, tiled=True)
            wd_f = jax.lax.all_gather(wd, data_axis, axis=1, tiled=True)
        else:
            wg_f, wu_f, wd_f = wg, wu, wd

        # local-expert dispatch (experts [me*e_loc, (me+1)*e_loc))
        me = jax.lax.axis_index(model_axis)
        e_start = me * e_loc
        flat_e = top_e.reshape(-1)
        flat_w = top_w.reshape(-1)
        flat_tok = jnp.repeat(jnp.arange(S), m.top_k)
        local = (flat_e >= e_start) & (flat_e < e_start + e_loc)
        rel_e = jnp.where(local, flat_e - e_start, e_loc)  # e_loc = drop bin
        C = max(int(S * m.top_k * m.capacity_factor / m.n_experts) + 1, m.top_k)
        order = jnp.argsort(rel_e, stable=True)
        sorted_e = rel_e[order]
        sorted_tok = flat_tok[order]
        sorted_w = jnp.where(local[order], flat_w[order], 0.0)
        counts = jnp.bincount(rel_e, length=e_loc + 1)[:e_loc]
        offsets = jnp.concatenate(
            [jnp.zeros(1, counts.dtype), jnp.cumsum(counts)[:-1]])
        slot_idx = offsets[:, None] + jnp.arange(C)[None, :]
        slot_valid = jnp.arange(C)[None, :] < counts[:, None]
        slot_idx = jnp.clip(slot_idx, 0, S * m.top_k - 1)
        tok_at_slot = jnp.where(slot_valid, sorted_tok[slot_idx], 0)
        w_at_slot = jnp.where(slot_valid, sorted_w[slot_idx], 0.0)

        xd = xf[tok_at_slot] * slot_valid[..., None].astype(xf.dtype)
        act = ACT["silu"]
        g = act(jnp.einsum("ecd,edf->ecf", xd, wg_f.astype(xd.dtype)))
        u = jnp.einsum("ecd,edf->ecf", xd, wu_f.astype(xd.dtype))
        y = jnp.einsum("ecf,efd->ecd", g * u, wd_f.astype(xd.dtype))
        y = y * w_at_slot[..., None].astype(y.dtype)
        out = jax.ops.segment_sum(
            y.reshape(-1, D), tok_at_slot.reshape(-1), num_segments=S
        ).astype(x_loc.dtype)
        # combine across model shards (each token's experts are spread)
        out = jax.lax.psum(out, model_axis)

        if m.n_shared:
            gs = act(xf @ sg.astype(x_loc.dtype))
            us = xf @ su.astype(x_loc.dtype)
            out = out + (gs * us) @ sd.astype(x_loc.dtype)

        # load-balance stats are global: average across data shards
        mean_probs = jax.lax.pmean(probs.mean(axis=0), data_axis)
        frac = jax.lax.pmean(
            jnp.bincount(flat_e, length=m.n_experts) / (S * m.top_k), data_axis
        )
        aux = m.n_experts * jnp.sum(mean_probs * frac)
        return out.reshape(B_loc, T, D), aux[None]

    from jax.experimental.shard_map import shard_map

    zero = jnp.zeros((1, 1), x.dtype)
    sg = params.get("shared_gate", zero)
    su = params.get("shared_up", zero)
    sd = params.get("shared_down", zero)
    fn = shard_map(
        shard_fn,
        mesh=mesh,
        in_specs=(
            P(),                                # router replicated
            P(model_axis, None, data_axis),     # wg (E, D, F)
            P(model_axis, None, data_axis),     # wu
            P(model_axis, data_axis, None),     # wd (E, F, D)
            P(), P(), P(),                      # shared experts replicated
            P(data_axis, None, None),           # x (B, T, D)
        ),
        out_specs=(P(data_axis, None, None), P()),
        check_rep=False,
    )
    out, aux = fn(params["router"], params["w_gate"], params["w_up"],
                  params["w_down"], sg, su, sd, x)
    return out, aux.sum().astype(jnp.float32)
