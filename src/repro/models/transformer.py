"""Decoder-only transformer assembly over a repeating block pattern.

Layers are *stacked* along a leading 'layers' axis and iterated with
``lax.scan`` over pattern groups, so HLO size is O(1) in depth (compile-time
essential for the 40-cell dry-run) and the remat policy applies per group.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import attention as attn_mod
from . import mlp as mlp_mod
from . import moe as moe_mod
from . import rglru as rglru_mod
from . import xlstm as xlstm_mod
from .common import (
    ParamBuilder,
    layer_norm,
    rms_norm,
    softcap,
    stack_layer_axes,
    stack_layer_params,
    unzip_params,
)
from .config import ModelConfig

MIXER_INIT = {
    "attn": attn_mod.init_attention,
    "attn_local": attn_mod.init_attention,
    "attn_global": attn_mod.init_attention,
    "rglru": rglru_mod.init_rglru_block,
    "mlstm": xlstm_mod.init_mlstm,
    "slstm": xlstm_mod.init_slstm,
}


def _norm(cfg: ModelConfig, params, x):
    if cfg.norm == "layernorm":
        return layer_norm(x, params["scale"], params.get("bias"))
    return rms_norm(x, params["scale"], scale_plus_one=cfg.rms_scale_plus_one)


def _init_norm(pb: ParamBuilder, cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    if cfg.norm == "layernorm":
        return {"scale": pb.ones((d,), ("embed",)), "bias": pb.zeros((d,), ("embed",))}
    init = pb.zeros if cfg.rms_scale_plus_one else pb.ones
    return {"scale": init((d,), ("embed",))}


def init_block(pb: ParamBuilder, cfg: ModelConfig, kind: str) -> Dict[str, Any]:
    p: Dict[str, Any] = {
        "norm1": _init_norm(pb, cfg),
        "mixer": MIXER_INIT[kind](pb, cfg),
    }
    has_mlp = cfg.d_ff > 0 or cfg.moe is not None
    if has_mlp:
        p["norm2"] = _init_norm(pb, cfg)
        p["mlp"] = (
            moe_mod.init_moe(pb, cfg) if cfg.moe is not None
            else mlp_mod.init_mlp(pb, cfg)
        )
    if cfg.post_block_norm:
        p["post_norm1"] = _init_norm(pb, cfg)
        if has_mlp:
            p["post_norm2"] = _init_norm(pb, cfg)
    return p


def apply_block(
    params: Dict[str, Any],
    x: jnp.ndarray,
    cfg: ModelConfig,
    kind: str,
    cache: Optional[Dict[str, Any]] = None,
    *,
    use_pallas: bool = False,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, Optional[Dict[str, Any]], jnp.ndarray]:
    """Returns (x', cache', aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = _norm(cfg, params["norm1"], x)
    if kind.startswith("attn"):
        local = kind == "attn_local"
        y, cache = attn_mod.attention(
            params["mixer"], h, cfg, local=local, cache=cache,
            use_pallas=use_pallas, interpret=interpret,
        )
    elif kind == "rglru":
        y, cache = rglru_mod.rglru_block(params["mixer"], h, cfg, cache)
    elif kind == "mlstm":
        y, cache = xlstm_mod.mlstm(params["mixer"], h, cfg, cache)
    elif kind == "slstm":
        y, cache = xlstm_mod.slstm(params["mixer"], h, cfg, cache)
    else:
        raise ValueError(kind)
    if cfg.post_block_norm:
        y = _norm(cfg, params["post_norm1"], y)
    x = x + y
    if "mlp" in params:
        h = _norm(cfg, params["norm2"], x)
        if cfg.moe is not None:
            y, aux = moe_mod.moe_block(params["mlp"], h, cfg)
        else:
            y = mlp_mod.mlp(params["mlp"], h, cfg)
        if cfg.post_block_norm:
            y = _norm(cfg, params["post_norm2"], y)
        x = x + y
    return x, cache, aux


# ---------------------------------------------------------------------------
# Full decoder stack
# ---------------------------------------------------------------------------

def init_decoder(pb: ParamBuilder, cfg: ModelConfig) -> Dict[str, Any]:
    """Build the stacked-parameter tree (values+axes zipped; unzip at top)."""
    groups = []
    for _ in range(cfg.n_groups):
        group = {
            f"b{j}": init_block(pb, cfg, kind)
            for j, kind in enumerate(cfg.block_pattern)
        }
        groups.append(group)
    # stack values; axes tree comes from one group with 'layers' prepended
    values = [unzip_params(g)[0] for g in groups]
    axes = unzip_params(groups[0])[1]
    stacked = stack_layer_params(values)
    stacked_axes = stack_layer_axes(axes)
    return stacked, stacked_axes


def decoder_stack(
    stacked_params: Dict[str, Any],
    x: jnp.ndarray,
    cfg: ModelConfig,
    caches: Optional[Dict[str, Any]] = None,
    *,
    use_pallas: bool = False,
    interpret: bool = False,
    remat: bool = False,
    unroll: bool = False,
    remat_policy: str = "full",
) -> Tuple[jnp.ndarray, Optional[Dict[str, Any]], jnp.ndarray]:
    """Scan the block-pattern groups. caches: tree stacked over groups.
    ``unroll`` unrolls the group scan (dry-run cost-analysis fidelity).
    ``remat_policy``: 'full' re-computes the whole group in backward (min
    memory, +2ND flops); 'dots' saves matmul outputs (no matmul recompute,
    more activation memory) — a §Perf hillclimb knob."""

    def group_fn(carry, xs):
        x, aux = carry
        gp, gc = xs
        new_caches = {}
        for j, kind in enumerate(cfg.block_pattern):
            c_j = gc[f"b{j}"] if gc is not None else None
            x, c_j, a = apply_block(
                gp[f"b{j}"], x, cfg, kind, c_j,
                use_pallas=use_pallas, interpret=interpret,
            )
            new_caches[f"b{j}"] = c_j
            aux = aux + a
        return (x, aux), new_caches

    if remat:
        policy = None
        if remat_policy == "dots":
            policy = jax.checkpoint_policies.checkpoint_dots
        group_fn = jax.checkpoint(group_fn, prevent_cse=False, policy=policy)

    if caches is None:
        (x, aux), _ = jax.lax.scan(
            lambda c, p: (group_fn(c, (p, None))[0], None),
            (x, jnp.zeros((), jnp.float32)),
            stacked_params,
            unroll=unroll,
        )
        return x, None, aux
    (x, aux), new_caches = jax.lax.scan(
        group_fn, (x, jnp.zeros((), jnp.float32)), (stacked_params, caches),
        unroll=unroll,
    )
    return x, new_caches, aux


def init_lm(key, cfg: ModelConfig):
    """Full LM init: returns (params, axes)."""
    pb = ParamBuilder(key=key, param_dtype=jnp.dtype(cfg.param_dtype))
    top: Dict[str, Any] = {}
    top["embed"] = pb.normal((cfg.vocab, cfg.d_model), ("vocab", "embed"), stddev=0.02)
    stacked, stacked_axes = init_decoder(pb, cfg)
    top["final_norm"] = _init_norm(pb, cfg)
    if not cfg.tie_embeddings:
        top["lm_head"] = pb.fan_in((cfg.d_model, cfg.vocab), ("embed", "vocab"), fan_axis=0)
    values, axes = unzip_params(top)
    values["blocks"] = stacked
    axes["blocks"] = stacked_axes
    return values, axes


def lm_forward(
    params: Dict[str, Any],
    tokens: Optional[jnp.ndarray],
    cfg: ModelConfig,
    *,
    embeds: Optional[jnp.ndarray] = None,
    caches: Optional[Dict[str, Any]] = None,
    use_pallas: bool = False,
    interpret: bool = False,
    remat: bool = False,
    unroll: bool = False,
    remat_policy: str = "full",
    last_only: bool = False,
) -> Tuple[jnp.ndarray, Optional[Dict[str, Any]], jnp.ndarray]:
    """tokens (B, T) and/or precomputed ``embeds`` (B, P, D) prefix (vlm/audio
    stubs). Returns (logits, caches', aux).

    ``last_only``: project only the final position through the LM head
    (prefill fast path — avoids materializing/all-reducing (B, T, vocab)
    logits; at 32k context x 200k vocab that is a ~50 GB fp32 tensor)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    parts = []
    if embeds is not None:
        parts.append(embeds.astype(cdt))
    if tokens is not None:
        emb = jnp.take(params["embed"], tokens, axis=0).astype(cdt)
        parts.append(emb)
    x = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, cdt)
    x, caches, aux = decoder_stack(
        params["blocks"], x, cfg, caches,
        use_pallas=use_pallas, interpret=interpret, remat=remat, unroll=unroll,
        remat_policy=remat_policy,
    )
    x = _norm(cfg, params["final_norm"], x)
    if last_only:
        x = x[:, -1:]
    head = params.get("lm_head")
    if head is None:
        logits = jnp.einsum("btd,vd->btv", x, params["embed"].astype(cdt))
    else:
        logits = jnp.einsum("btd,dv->btv", x, head.astype(cdt))
    logits = softcap(logits.astype(jnp.float32), cfg.final_softcap)
    return logits, caches, aux


def init_lm_caches(cfg: ModelConfig, batch: int, max_len: int, dtype,
                   ring_local: bool = False) -> Dict[str, Any]:
    """Cache tree stacked over groups, keyed by pattern position.

    ``ring_local``: local (sliding-window) layers get a bounded ring buffer
    of exactly ``window`` slots instead of a full-context buffer — O(window)
    memory/bandwidth per decode step (decode-only; see attention.py)."""

    def one(kind):
        if kind.startswith("attn"):
            if (ring_local and kind == "attn_local" and cfg.window is not None
                    and cfg.window < max_len):
                c = attn_mod.init_cache(cfg, batch, cfg.window, dtype)
                c["ring"] = jnp.ones((), jnp.int32)
                return c
            return attn_mod.init_cache(cfg, batch, max_len, dtype)
        if kind == "rglru":
            return rglru_mod.init_rglru_state(cfg, batch)
        if kind == "mlstm":
            return xlstm_mod.init_mlstm_state(cfg, batch)
        if kind == "slstm":
            return xlstm_mod.init_slstm_state(cfg, batch)
        raise ValueError(kind)

    caches = {}
    for j, kind in enumerate(cfg.block_pattern):
        c = one(kind)
        caches[f"b{j}"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (cfg.n_groups,) + a.shape), c
        )
    return caches
