"""RecurrentGemma / Griffin recurrent block: causal conv + RG-LRU.

RG-LRU (arXiv:2402.19427):
  r_t = sigmoid(W_a x_t + b_a)            recurrence gate
  i_t = sigmoid(W_x x_t + b_x)            input gate
  log a_t = -c * softplus(Lambda) * r_t   (c = 8)
  h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training/prefill uses ``jax.lax.associative_scan`` over the linear
recurrence (h_t = a_t h_{t-1} + b_t is associative), giving O(log T) depth —
the TPU-native replacement for the paper-series' CUDA linear-scan kernel.
Decode is a single fused step with O(1) state, which is why
recurrentgemma-2b runs the long_500k shape.

Block layout (Griffin fig. 2): two branches from the input — (linear ->
GeLU) gate and (linear -> causal conv1d(4) -> RG-LRU) — merged by product,
then down-projected.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import ParamBuilder
from .config import ModelConfig

_C = 8.0


def init_rglru_block(pb: ParamBuilder, cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    w = cfg.lru_width or d
    cw = cfg.conv_width
    return {
        "w_gate": pb.fan_in((d, w), ("embed", "state"), fan_axis=0),
        "w_x": pb.fan_in((d, w), ("embed", "state"), fan_axis=0),
        "conv": pb.normal((cw, w), (None, "state"), stddev=cw ** -0.5),
        "conv_b": pb.zeros((w,), ("state",)),
        "wa": pb.fan_in((w, w), ("state", None), fan_axis=0),
        "ba": pb.zeros((w,), ("state",)),
        "wi": pb.fan_in((w, w), ("state", None), fan_axis=0),
        "bi": pb.zeros((w,), ("state",)),
        # Lambda init so that a (at r=1) is uniform in [0.9, 0.999]:
        # log a = -c*softplus(Lambda)  =>  Lambda = log(expm1(-log(a)/c))
        "lam": pb.const(
            jnp.log(jnp.expm1(-jnp.log(jnp.linspace(0.9, 0.999, w)) / _C)),
            ("state",),
        ),
        "w_down": pb.fan_in((w, d), ("state", "embed"), fan_axis=0),
    }


def _causal_conv(x: jnp.ndarray, kernel: jnp.ndarray, bias: jnp.ndarray,
                 prev: Optional[jnp.ndarray]) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Depthwise causal conv along T. x: (B, T, W); kernel: (cw, W).
    prev: (B, cw-1, W) history for decode. Returns (y, new_prev)."""
    cw = kernel.shape[0]
    if prev is None:
        prev = jnp.zeros((x.shape[0], cw - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([prev, x], axis=1)                       # (B, T+cw-1, W)
    y = sum(
        xp[:, i : i + x.shape[1], :] * kernel[i][None, None, :]
        for i in range(cw)
    ) + bias[None, None, :]
    new_prev = xp[:, -(cw - 1):, :] if cw > 1 else prev
    return y.astype(x.dtype), new_prev


def _rglru_scan(xs: jnp.ndarray, params, h0: Optional[jnp.ndarray]):
    """xs: (B, T, W) conv output. Returns (h (B,T,W), h_last)."""
    f32 = jnp.float32
    x = xs.astype(f32)
    r = jax.nn.sigmoid(x @ params["wa"].astype(f32) + params["ba"].astype(f32))
    i = jax.nn.sigmoid(x @ params["wi"].astype(f32) + params["bi"].astype(f32))
    log_a = -_C * jax.nn.softplus(params["lam"].astype(f32))[None, None, :] * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 0.0)) * (i * x)
    if h0 is not None:
        # absorb the carried state as a virtual first step: h_0 given.
        a = jnp.concatenate([jnp.ones_like(a[:, :1]), a], axis=1)
        b = jnp.concatenate([h0[:, None, :].astype(f32), b], axis=1)

    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    if h0 is not None:
        h = h[:, 1:]
    return h, h[:, -1]


def rglru_block(
    params: Dict[str, Any], x: jnp.ndarray, cfg: ModelConfig,
    state: Optional[Dict[str, jnp.ndarray]] = None,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """x: (B, T, D). state: {"h": (B, W), "conv": (B, cw-1, W)}."""
    gate = jax.nn.gelu(x @ params["w_gate"].astype(x.dtype), approximate=True)
    u = x @ params["w_x"].astype(x.dtype)
    prev = state["conv"].astype(x.dtype) if state is not None else None
    u, new_conv = _causal_conv(u, params["conv"].astype(x.dtype), params["conv_b"].astype(x.dtype), prev)
    h0 = state["h"] if state is not None else None
    h, h_last = _rglru_scan(u, params, h0)
    y = (h.astype(x.dtype) * gate) @ params["w_down"].astype(x.dtype)
    return y, {"h": h_last, "conv": new_conv}


def init_rglru_state(cfg: ModelConfig, batch: int) -> Dict[str, jnp.ndarray]:
    w = cfg.lru_width or cfg.d_model
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, w), jnp.float32),
    }
