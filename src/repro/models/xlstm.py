"""xLSTM blocks: sLSTM (scalar memory) and mLSTM (matrix memory).

Faithful to arXiv:2405.04517 cell equations with exponential gating and the
max-stabilizer state m_t:

  sLSTM:  c_t = f' c_{t-1} + i' z ;  n_t = f' n_{t-1} + i' ;  h = o * c/n
  mLSTM:  C_t = f' C_{t-1} + i' v k^T ;  n_t = f' n_{t-1} + i' k
          h~  = C_t q / max(|n_t . q|, 1) ;  h = o * h~
  where  m_t = max(f~ + m_{t-1}, i~),  i' = exp(i~ - m_t),
         f' = exp(f~ + m_{t-1} - m_t).

Both cells run as ``lax.scan`` over time (exact recurrence; O(1) HLO in T,
O(1) state in sequence length — which is why xlstm-350m runs the long_500k
decode shape).  Block-level simplifications vs. the paper's figure-9
skeleton (documented in DESIGN.md): the mLSTM block's causal conv is
omitted; projections are fused per cell.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import ParamBuilder
from .config import ModelConfig


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm(pb: ParamBuilder, cfg: ModelConfig) -> Dict[str, Any]:
    d, H = cfg.d_model, cfg.n_heads
    dh = d // H
    # input projections for (z, i, f, o); recurrent weights are block-diagonal
    # per head: (H, dh, dh).
    return {
        "w_in": pb.fan_in((d, 4, H, dh), ("embed", None, "heads", "head_dim"), fan_axis=0),
        "r": pb.fan_in((4, H, dh, dh), (None, "heads", "head_dim", None), fan_axis=2),
        "b": pb.zeros((4, H, dh), (None, "heads", "head_dim")),
        "w_out": pb.fan_in((H, dh, d), ("heads", "head_dim", "embed"), fan_axis=(0, 1)),
    }


def slstm(
    params: Dict[str, Any], x: jnp.ndarray, cfg: ModelConfig,
    state: Optional[Dict[str, jnp.ndarray]] = None,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """x: (B, T, D). state: {c, n, m, h} each (B, H, dh). Returns (y, state')."""
    B, T, D = x.shape
    H = cfg.n_heads
    dh = D // H
    if state is None:
        z = jnp.zeros((B, H, dh), jnp.float32)
        state = {"c": z, "n": z, "m": jnp.full((B, H, dh), -1e30), "h": z}

    pre = jnp.einsum("btd,dghk->btghk", x, params["w_in"].astype(x.dtype))  # (B,T,4,H,dh)
    r = params["r"].astype(jnp.float32)
    b = params["b"].astype(jnp.float32)

    def step(s, pre_t):
        # recurrent contribution from h_{t-1} (block-diagonal per head)
        rec = jnp.einsum("bhk,ghkl->bghl", s["h"], r)            # (B,4,H,dh)
        g = pre_t.astype(jnp.float32) + rec + b[None]
        z_t = jnp.tanh(g[:, 0])
        i_t = g[:, 1]
        f_t = g[:, 2]
        o_t = jax.nn.sigmoid(g[:, 3])
        m_new = jnp.maximum(f_t + s["m"], i_t)
        i_p = jnp.exp(i_t - m_new)
        f_p = jnp.exp(f_t + s["m"] - m_new)
        c = f_p * s["c"] + i_p * z_t
        n = f_p * s["n"] + i_p
        h = o_t * c / jnp.maximum(jnp.abs(n), 1e-6)
        return {"c": c, "n": n, "m": m_new, "h": h}, h

    state, hs = jax.lax.scan(step, state, pre.swapaxes(0, 1))
    y = jnp.einsum("tbhk,hkd->btd", hs.astype(x.dtype), params["w_out"].astype(x.dtype))
    return y, state


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def init_mlstm(pb: ParamBuilder, cfg: ModelConfig) -> Dict[str, Any]:
    d, H = cfg.d_model, cfg.n_heads
    di = int(d * cfg.mlstm_proj_factor)
    dh = di // H
    return {
        "w_up": pb.fan_in((d, di), ("embed", "ff"), fan_axis=0),
        "w_gate": pb.fan_in((d, di), ("embed", "ff"), fan_axis=0),
        "wq": pb.fan_in((di, H, dh), ("ff", "heads", "head_dim"), fan_axis=0),
        "wk": pb.fan_in((di, H, dh), ("ff", "heads", "head_dim"), fan_axis=0),
        "wv": pb.fan_in((di, H, dh), ("ff", "heads", "head_dim"), fan_axis=0),
        "w_if": pb.fan_in((di, 2, H), ("ff", None, "heads"), fan_axis=0),
        "b_if": pb.const(jnp.zeros((2, 1)) + jnp.array([[0.0], [1.0]]), (None, "heads")),
        "w_down": pb.fan_in((di, d), ("ff", "embed"), fan_axis=0),
    }


def mlstm(
    params: Dict[str, Any], x: jnp.ndarray, cfg: ModelConfig,
    state: Optional[Dict[str, jnp.ndarray]] = None,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """x: (B, T, D). state: {C (B,H,dh,dh), n (B,H,dh), m (B,H)}."""
    B, T, D = x.shape
    H = cfg.n_heads
    di = int(D * cfg.mlstm_proj_factor)
    dh = di // H
    up = x @ params["w_up"].astype(x.dtype)                       # (B,T,di)
    gate = jax.nn.silu(x @ params["w_gate"].astype(x.dtype))
    q = jnp.einsum("bti,ihk->bthk", up, params["wq"].astype(x.dtype))
    k = jnp.einsum("bti,ihk->bthk", up, params["wk"].astype(x.dtype)) / (dh ** 0.5)
    v = jnp.einsum("bti,ihk->bthk", up, params["wv"].astype(x.dtype))
    gif = jnp.einsum("bti,igh->btgh", up, params["w_if"].astype(x.dtype))
    gif = gif.astype(jnp.float32) + params["b_if"].astype(jnp.float32)[None, None]

    if state is None:
        state = {
            "C": jnp.zeros((B, H, dh, dh), jnp.float32),
            "n": jnp.zeros((B, H, dh), jnp.float32),
            "m": jnp.full((B, H), -1e30, jnp.float32),
        }

    def step(s, inp):
        q_t, k_t, v_t, gif_t = inp                                # (B,H,dh) x3, (B,2,H)
        i_t, f_t = gif_t[:, 0], jax.nn.log_sigmoid(gif_t[:, 1])   # (B,H)
        m_new = jnp.maximum(f_t + s["m"], i_t)
        i_p = jnp.exp(i_t - m_new)[..., None]                     # (B,H,1)
        f_p = jnp.exp(f_t + s["m"] - m_new)[..., None]
        kf, vf, qf = (a.astype(jnp.float32) for a in (k_t, v_t, q_t))
        C = f_p[..., None] * s["C"] + i_p[..., None] * vf[..., :, None] * kf[..., None, :]
        n = f_p * s["n"] + i_p * kf
        num = jnp.einsum("bhvk,bhk->bhv", C, qf)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, qf)), 1.0)
        h = num / den[..., None]
        return {"C": C, "n": n, "m": m_new}, h

    xs = (q.swapaxes(0, 1), k.swapaxes(0, 1), v.swapaxes(0, 1), gif.swapaxes(0, 1))
    state, hs = jax.lax.scan(step, state, xs)
    h = hs.swapaxes(0, 1).reshape(B, T, di).astype(x.dtype)       # merge heads
    y = (h * gate) @ params["w_down"].astype(x.dtype)
    return y, state


def init_slstm_state(cfg: ModelConfig, batch: int) -> Dict[str, jnp.ndarray]:
    H, dh = cfg.n_heads, cfg.d_model // cfg.n_heads
    z = jnp.zeros((batch, H, dh), jnp.float32)
    return {"c": z, "n": z, "m": jnp.full((batch, H, dh), -1e30), "h": z}


def init_mlstm_state(cfg: ModelConfig, batch: int) -> Dict[str, jnp.ndarray]:
    H = cfg.n_heads
    di = int(cfg.d_model * cfg.mlstm_proj_factor)
    dh = di // H
    return {
        "C": jnp.zeros((batch, H, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, H, dh), jnp.float32),
        "m": jnp.full((batch, H), -1e30, jnp.float32),
    }
