"""Architecture configuration schema.

One ``ModelConfig`` describes every assigned architecture via a repeating
*block pattern* (the unit that ``lax.scan`` iterates), e.g.:

  dense llama-style      : ("attn",)
  gemma2 local/global    : ("attn_local", "attn_global")
  recurrentgemma 2:1     : ("rglru", "rglru", "attn_local")
  xlstm m/s alternation  : ("mlstm", "slstm")

Each block is (sequence-mixer + MLP/MoE) with pre-norms; mixer-specific
fields live in the config.  ``[audio]``/``[vlm]`` archs set ``frontend`` and
receive precomputed frame/patch embeddings from ``input_specs()`` (stub per
the assignment).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0            # shared (always-on) experts
    capacity_factor: float = 1.25
    router_softcap: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None          # default d_model // n_heads
    block_pattern: Tuple[str, ...] = ("attn",)
    # attention
    rope_theta: float = 10000.0
    window: Optional[int] = None            # sliding window for *_local blocks
    attn_softcap: Optional[float] = None    # gemma2 attention logit cap
    final_softcap: Optional[float] = None   # gemma2 final logit cap
    attn_bias: bool = False
    # mlp
    mlp: str = "swiglu"                     # swiglu | geglu | gelu
    moe: Optional[MoEConfig] = None
    # norms / embeddings
    norm: str = "rmsnorm"                   # rmsnorm | layernorm
    post_block_norm: bool = False           # gemma2 post-norms
    rms_scale_plus_one: bool = False        # gemma-style (1+w)
    tie_embeddings: bool = True
    embed_scale: bool = False               # gemma: x * sqrt(d_model)
    # recurrent blocks
    lru_width: Optional[int] = None         # RG-LRU state width
    conv_width: int = 4                     # temporal conv in recurrent block
    mlstm_proj_factor: float = 2.0
    # encoder-decoder (whisper)
    enc_dec: bool = False
    n_enc_layers: int = 0
    enc_frames: int = 1500                  # whisper audio frames (stubbed)
    # modality frontend stub: none | audio_stub | vision_stub
    frontend: str = "none"
    n_patches: int = 2880                   # llava anyres patch count (stub)
    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    # attention sub-quadratic? (drives long_500k applicability)
    family: str = "dense"                   # dense | moe | ssm | hybrid | audio | vlm

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def pattern_period(self) -> int:
        return len(self.block_pattern)

    @property
    def n_groups(self) -> int:
        if self.n_layers % self.pattern_period:
            raise ValueError(
                f"{self.name}: n_layers={self.n_layers} not divisible by "
                f"pattern period {self.pattern_period}"
            )
        return self.n_layers // self.pattern_period

    @property
    def sub_quadratic(self) -> bool:
        """True if no block attends over unbounded context (long_500k rule)."""
        return all(b in ("rglru", "mlstm", "slstm", "attn_local")
                   for b in self.block_pattern)

    def n_params(self) -> int:
        """Approximate parameter count (for 6ND model-flops accounting)."""
        d, hd = self.d_model, self.head_dim_
        attn = d * (self.n_heads + 2 * self.n_kv_heads) * hd + self.n_heads * hd * d
        if self.moe is not None:
            ff_dense = 0
            moe = self.moe.n_experts * 3 * d * self.moe.d_ff_expert + d * self.moe.n_experts
            moe += self.moe.n_shared * 3 * d * self.moe.d_ff_expert
        else:
            ff_dense = 3 * d * self.d_ff if self.mlp in ("swiglu", "geglu") else 2 * d * self.d_ff
            moe = 0
        per_block = {}
        for b in set(self.block_pattern):
            if b.startswith("attn"):
                mix = attn
            elif b == "rglru":
                w = self.lru_width or d
                mix = 2 * d * w + w * d + 3 * w + w * self.conv_width
            elif b == "mlstm":
                di = int(d * self.mlstm_proj_factor)
                mix = 2 * d * di + 3 * di * di // max(self.n_heads, 1) + di * d
            elif b == "slstm":
                mix = 8 * d * d // max(self.n_heads, 1) + d * d
            else:
                raise ValueError(b)
            per_block[b] = mix + ff_dense + moe + 2 * d
        body = self.n_groups * sum(per_block[b] for b in self.block_pattern)
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        enc = 0
        if self.enc_dec:
            enc = self.n_enc_layers * (attn + ff_dense + 2 * d) + body // self.n_layers * 0
            body += self.n_layers * (self.n_heads * hd * d + d * (self.n_heads + 2 * self.n_kv_heads) * hd)  # cross-attn
        return int(body + emb + enc)

    def n_active_params(self) -> int:
        """Active params per token (MoE: only top_k + shared experts)."""
        if self.moe is None:
            return self.n_params()
        full = self.n_params()
        moe_all = self.n_layers * self.moe.n_experts * 3 * self.d_model * self.moe.d_ff_expert
        moe_act = self.n_layers * (self.moe.top_k + self.moe.n_shared) * 3 * self.d_model * self.moe.d_ff_expert
        return int(full - moe_all + moe_act)
