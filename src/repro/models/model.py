"""Public model API: build_model(cfg) -> Model with init/loss/prefill/decode."""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import encdec as encdec_mod
from . import transformer as tf_mod
from .config import ModelConfig


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean token CE in fp32. logits (B,T,V) fp32, labels (B,T) int32."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return (lse - gold).mean()


@dataclasses.dataclass
class Model:
    """Bundle of pure functions for one architecture."""

    cfg: ModelConfig
    init: Callable[[jax.Array], Tuple[Any, Any]]          # key -> (params, axes)
    loss_fn: Callable[..., jnp.ndarray]                   # (params, batch) -> loss
    forward: Callable[..., jnp.ndarray]                   # logits
    prefill: Callable[..., Tuple[jnp.ndarray, Any]]
    decode_step: Callable[..., Tuple[jnp.ndarray, Any]]
    init_cache: Callable[..., Any]                        # (batch, max_len) -> cache


def build_model(cfg: ModelConfig, *, use_pallas: bool = False,
                interpret: bool = False, remat: bool = False,
                unroll_scans: bool = False, remat_policy: str = "full",
                ring_local: bool = False) -> Model:
    cdt = jnp.dtype(cfg.compute_dtype)

    if cfg.enc_dec:
        return _build_encdec(cfg, use_pallas, interpret, unroll_scans)

    def init(key):
        return tf_mod.init_lm(key, cfg)

    def forward(params, tokens, embeds=None):
        logits, _, _ = tf_mod.lm_forward(
            params, tokens, cfg, embeds=embeds,
            use_pallas=use_pallas, interpret=interpret, unroll=unroll_scans,
        )
        return logits

    def loss_fn(params, batch):
        """batch: {"tokens": (B,T), "labels": (B,T), optional "embeds"}."""
        logits, _, aux = tf_mod.lm_forward(
            params, batch["tokens"], cfg, embeds=batch.get("embeds"),
            use_pallas=use_pallas, interpret=interpret, remat=remat,
            unroll=unroll_scans, remat_policy=remat_policy,
        )
        labels = batch["labels"]
        if batch.get("embeds") is not None:
            # loss only over the token suffix (stub prefix carries no labels)
            logits = logits[:, -labels.shape[1]:]
        return cross_entropy(logits, labels) + 0.01 * aux

    def prefill(params, tokens, cache, embeds=None):
        logits, cache, _ = tf_mod.lm_forward(
            params, tokens, cfg, embeds=embeds, caches=cache,
            use_pallas=use_pallas, interpret=interpret, unroll=unroll_scans,
            last_only=True,
        )
        return logits[:, -1], cache

    def decode_step(params, tokens, cache):
        """tokens: (B, 1) -> (logits (B, V), cache')."""
        logits, cache, _ = tf_mod.lm_forward(
            params, tokens, cfg, caches=cache,
            use_pallas=use_pallas, interpret=interpret, unroll=unroll_scans,
        )
        return logits[:, -1], cache

    def init_cache(batch, max_len, dtype=None):
        return tf_mod.init_lm_caches(cfg, batch, max_len, dtype or cdt,
                                     ring_local=ring_local)

    return Model(cfg, init, loss_fn, forward, prefill, decode_step, init_cache)


def _build_encdec(cfg: ModelConfig, use_pallas: bool, interpret: bool,
                  unroll_scans: bool = False) -> Model:
    def init(key):
        return encdec_mod.init_encdec(key, cfg)

    def forward(params, tokens, embeds=None):
        enc = encdec_mod.encode(
            params, embeds, cfg, use_pallas=use_pallas, interpret=interpret,
            unroll=unroll_scans,
        )
        logits, _ = encdec_mod.decode(
            params, tokens, enc, cfg, use_pallas=use_pallas,
            interpret=interpret, unroll=unroll_scans,
        )
        return logits

    def loss_fn(params, batch):
        logits = forward(params, batch["tokens"], batch["embeds"])
        return cross_entropy(logits, batch["labels"])

    def prefill(params, tokens, cache, embeds=None):
        enc = encdec_mod.encode(
            params, embeds, cfg, use_pallas=use_pallas, interpret=interpret,
            unroll=unroll_scans,
        )
        # project the encoder output through every layer's cross-attn K/V
        # ONCE — decode steps reuse it (the enc-dec decode hot-spot fix)
        cross = encdec_mod.compute_cross_kv(params, enc, cfg)
        cache = dict(cache, cross_kv=cross)
        logits, dec_c = encdec_mod.decode(
            params, tokens, enc, cfg, cache["dec"],
            use_pallas=use_pallas, interpret=interpret, unroll=unroll_scans,
            last_only=True, cross_kv=cross,
        )
        cache = dict(cache, dec=dec_c)
        return logits[:, -1], cache

    def decode_step(params, tokens, cache):
        logits, dec_c = encdec_mod.decode(
            params, tokens, None, cfg, cache["dec"],
            use_pallas=use_pallas, interpret=interpret, unroll=unroll_scans,
            cross_kv=cache["cross_kv"],
        )
        return logits[:, -1], dict(cache, dec=dec_c)

    def init_cache(batch, max_len, dtype=None):
        cdt = dtype or jnp.dtype(cfg.compute_dtype)
        hkv, hd = cfg.n_kv_heads, cfg.head_dim_
        return {
            "dec": encdec_mod.init_dec_caches(cfg, batch, max_len, cdt),
            "cross_kv": {
                "k": jnp.zeros((cfg.n_layers, batch, hkv, cfg.enc_frames, hd), cdt),
                "v": jnp.zeros((cfg.n_layers, batch, hkv, cfg.enc_frames, hd), cdt),
            },
        }

    return Model(cfg, init, loss_fn, forward, prefill, decode_step, init_cache)
