"""Model substrate: param system with logical sharding axes, norms, RoPE.

Params are nested dicts of ``jnp`` arrays.  Every initializer also produces a
*matching* pytree of logical-axis tuples (e.g. ``("embed", "heads",
"head_dim")``) built by the same code path, so the distribution layer
(`repro.parallel.sharding`) can map logical axes -> mesh axes without any
name registry drift.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


# ---------------------------------------------------------------------------
# Param construction: values + logical axes built together
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ParamBuilder:
    """Accumulates (value, axes) leaf pairs under one RNG stream."""

    key: jax.Array
    param_dtype: Any = jnp.float32

    def _next(self) -> jax.Array:
        self.key, sub = jax.random.split(self.key)
        return sub

    def normal(self, shape, axes, stddev=0.02):
        v = jax.random.normal(self._next(), shape, jnp.float32) * stddev
        return v.astype(self.param_dtype), axes

    def fan_in(self, shape, axes, fan_axis=0):
        fan = shape[fan_axis] if isinstance(fan_axis, int) else 1
        if not isinstance(fan_axis, int):
            fan = 1
            for ax in fan_axis:
                fan *= shape[ax]
        std = fan ** -0.5
        v = jax.random.normal(self._next(), shape, jnp.float32) * std
        return v.astype(self.param_dtype), axes

    def zeros(self, shape, axes):
        return jnp.zeros(shape, self.param_dtype), axes

    def ones(self, shape, axes):
        return jnp.ones(shape, self.param_dtype), axes

    def const(self, value, axes):
        return jnp.asarray(value, self.param_dtype), axes


def unzip_params(tree: PyTree) -> Tuple[PyTree, PyTree]:
    """Split a tree of (value, axes) leaf pairs into (values, axes) trees."""
    is_leaf = lambda x: isinstance(x, tuple) and len(x) == 2 and (
        isinstance(x[0], (jnp.ndarray, jax.Array))
    )
    values = jax.tree.map(lambda x: x[0], tree, is_leaf=is_leaf)
    axes = jax.tree.map(lambda x: x[1], tree, is_leaf=is_leaf)
    return values, axes


def stack_layer_params(per_layer: list) -> PyTree:
    """Stack a list of identical param trees along a new leading 'layers'
    axis (for lax.scan over layers)."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *per_layer)


def stack_layer_axes(axes_tree: PyTree) -> PyTree:
    """Prepend the 'layers' logical axis to every leaf's axes tuple."""
    return jax.tree.map(
        lambda a: ("layers",) + tuple(a),
        axes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x
        ),
    )


# ---------------------------------------------------------------------------
# Norms / activations
# ---------------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6,
             scale_plus_one: bool = False) -> jnp.ndarray:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    s = scale.astype(jnp.float32)
    if scale_plus_one:  # gemma-style (1 + w)
        s = 1.0 + s
    return (y * s).astype(dt)


def layer_norm(x: jnp.ndarray, scale: jnp.ndarray, bias: Optional[jnp.ndarray],
               eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = x32.mean(axis=-1, keepdims=True)
    var = jnp.mean((x32 - mu) ** 2, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return y.astype(dt)


def softcap(x: jnp.ndarray, cap: Optional[float]) -> jnp.ndarray:
    """gemma2-style logit soft-capping: cap * tanh(x / cap)."""
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


ACT = {
    "silu": jax.nn.silu,
    "gelu": lambda x: jax.nn.gelu(x, approximate=True),
    "relu": jax.nn.relu,
    "tanh": jnp.tanh,
}


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 10000.0) -> jnp.ndarray:
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)  # (head_dim/2,)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float = 10000.0) -> jnp.ndarray:
    """x: (B, H, T, D) with D even; positions: (B, T) or (T,) absolute."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[:, None, :, None].astype(jnp.float32) * freqs  # (B,1,T,D/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(t: int, d: int, max_ts: float = 10000.0) -> jnp.ndarray:
    """Classic sin/cos table (whisper encoder): (t, d)."""
    pos = jnp.arange(t, dtype=jnp.float32)[:, None]
    div = jnp.exp(-jnp.log(max_ts) * jnp.arange(0, d, 2, jnp.float32) / d)
    pe = jnp.zeros((t, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe
