"""GQA attention block: full/local (sliding-window), softcap, RoPE, KV cache."""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..kernels.flash_attention import flash_attention
from ..kernels.flash_attention.ops import CHUNKED_THRESHOLD
from ..kernels.flash_attention.ref import attention_chunked, attention_ref
from .common import ParamBuilder, apply_rope
from .config import ModelConfig

# §Perf knob: when the KV cache is head_dim-sharded over 'model' (kv_heads
# don't divide the axis), contracting scores over the sharded head_dim makes
# GSPMD all-reduce (B,H,Tq,chunk)-sized SCORES (tens of GB at 32k).  Setting
# kv_gather to the batch axis name (or () for unsharded batch) constrains
# k/v to be gathered over 'model' before attention instead — an AG of the
# MB-scale cache slice per layer, with attention computed model-replicated.
ATTN_OPTS = {"kv_gather": None}


def set_attn_opts(kv_gather=None) -> None:
    ATTN_OPTS["kv_gather"] = kv_gather


def _maybe_gather_kv(ck, cv):
    spec = ATTN_OPTS["kv_gather"]
    if spec is None:
        return ck, cv
    from jax.sharding import PartitionSpec as P

    p = P(spec if spec else None, None, None, None)
    try:
        return (jax.lax.with_sharding_constraint(ck, p),
                jax.lax.with_sharding_constraint(cv, p))
    except Exception:
        return ck, cv


def init_attention(pb: ParamBuilder, cfg: ModelConfig) -> Dict[str, Any]:
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    p = {
        "wq": pb.fan_in((d, hq, hd), ("embed", "heads", "head_dim"), fan_axis=0),
        "wk": pb.fan_in((d, hkv, hd), ("embed", "kv_heads", "head_dim"), fan_axis=0),
        "wv": pb.fan_in((d, hkv, hd), ("embed", "kv_heads", "head_dim"), fan_axis=0),
        "wo": pb.fan_in((hq, hd, d), ("heads", "head_dim", "embed"), fan_axis=(0, 1)),
    }
    if cfg.attn_bias:
        p["bq"] = pb.zeros((hq, hd), ("heads", "head_dim"))
        p["bk"] = pb.zeros((hkv, hd), ("kv_heads", "head_dim"))
        p["bv"] = pb.zeros((hkv, hd), ("kv_heads", "head_dim"))
        p["bo"] = pb.zeros((d,), ("embed",))
    return p


def init_cross_attention(pb: ParamBuilder, cfg: ModelConfig) -> Dict[str, Any]:
    return init_attention(pb, cfg)


def _project(params, x, use_rope, positions, cfg):
    """x: (B, T, D) -> q (B,Hq,T,hd), k/v (B,Hkv,T,hd)."""
    q = jnp.einsum("btd,dhk->bhtk", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("btd,dhk->bhtk", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("btd,dhk->bhtk", x, params["wv"].astype(x.dtype))
    if "bq" in params:
        q = q + params["bq"].astype(x.dtype)[None, :, None, :]
        k = k + params["bk"].astype(x.dtype)[None, :, None, :]
        v = v + params["bv"].astype(x.dtype)[None, :, None, :]
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _out(params, o):
    """(B, Hq, T, hd) -> (B, T, D)."""
    y = jnp.einsum("bhtk,hkd->btd", o, params["wo"].astype(o.dtype))
    if "bo" in params:
        y = y + params["bo"].astype(o.dtype)
    return y


def attention(
    params: Dict[str, Any],
    x: jnp.ndarray,                       # (B, T, D)
    cfg: ModelConfig,
    *,
    local: bool = False,
    causal: bool = True,
    positions: Optional[jnp.ndarray] = None,
    cache: Optional[Dict[str, jnp.ndarray]] = None,
    use_rope: bool = True,
    use_pallas: bool = False,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    """Self-attention with optional KV cache.

    cache: {"k": (B,Hkv,Tmax,hd), "v": ..., "pos": scalar int32} — decode
    appends at ``pos`` and attends over the valid prefix.  Returns (y, cache').
    """
    B, T, _ = x.shape
    window = cfg.window if local else None
    if positions is None:
        base = 0 if cache is None else cache["pos"]
        positions = base + jnp.arange(T)[None, :]
        positions = jnp.broadcast_to(positions, (B, T))
    q, k, v = _project(params, x, use_rope, positions, cfg)

    if cache is not None and "ring" in cache:
        # Bounded ring buffer for local (sliding-window) layers: the buffer
        # holds exactly the last `window` tokens, so a 500k-token decode
        # reads O(window) KV instead of O(context) — recurrentgemma's
        # bounded-memory property realized in the cache layout.
        if T != 1:
            raise ValueError("ring caches support decode (T=1) only")
        pos = cache["pos"]
        wbuf = cache["k"].shape[2]
        slot = pos % wbuf
        ck = _dyn_update(jnp.asarray(cache["k"], k.dtype), k, slot)
        cv = _dyn_update(jnp.asarray(cache["v"], v.dtype), v, slot)
        new_cache = {"k": ck, "v": cv, "pos": pos + 1, "ring": cache["ring"]}
        valid = jnp.minimum(pos + 1, wbuf)
        # every stored token is within the window of the current query and
        # in its past — plain masked attention over the valid slots.
        o = attention_ref(
            q, ck, cv, causal=False, window=None, softcap=cfg.attn_softcap,
            q_offset=0, kv_len=jnp.full((B,), valid, jnp.int32),
        )
        return _out(params, o), new_cache

    if cache is not None:
        pos = cache["pos"]
        ck = jnp.asarray(cache["k"], k.dtype)
        cv = jnp.asarray(cache["v"], v.dtype)
        ck = _dyn_update(ck, k, pos)
        cv = _dyn_update(cv, v, pos)
        new_cache = {"k": ck, "v": cv, "pos": pos + T}
        ck, cv = _maybe_gather_kv(ck, cv)
        kv_len = pos + T
        # mask out beyond kv_len via big-negative trick inside ref path
        o = _attend_cached(
            q, ck, cv, kv_len, pos, cfg, window=window, causal=causal,
            use_pallas=use_pallas, interpret=interpret,
        )
        return _out(params, o), new_cache

    o = flash_attention(
        q, k, v, causal=causal, window=window, softcap=cfg.attn_softcap,
        use_pallas=use_pallas, interpret=interpret,
    )
    return _out(params, o), None


def _dyn_update(cache: jnp.ndarray, new: jnp.ndarray, pos) -> jnp.ndarray:
    return jax.lax.dynamic_update_slice(cache, new, (0, 0, pos, 0))


def _attend_cached(q, ck, cv, kv_len, q_offset, cfg, *, window, causal,
                   use_pallas, interpret):
    """Attention against the cache with a dynamic valid length.

    The kernel path requires static lengths; for decode we attend over the
    whole cache buffer with masking by position (padding keys are zeros but
    masked out by the kv_len comparison inside the reference / the causal
    frontier in the kernel).
    """
    B = q.shape[0]
    kv_len_vec = jnp.full((B,), kv_len, jnp.int32)
    q_pos = q_offset  # scalar traced offset
    # Reference paths support traced offsets/lengths; the Pallas kernel wants
    # static offsets, so serving uses the jnp paths (chunked for long caches
    # — O(T*chunk) memory instead of a (T_cache)^2 / B*H*T score blowup).
    if ck.shape[2] > CHUNKED_THRESHOLD:
        from ..kernels.flash_attention.ops import CHUNK_OPTS

        return attention_chunked(
            q, ck, cv, causal=causal, window=window, softcap=cfg.attn_softcap,
            q_offset=q_pos, kv_len=kv_len_vec, **CHUNK_OPTS,
        )
    return attention_ref(
        q, ck, cv, causal=causal, window=window, softcap=cfg.attn_softcap,
        q_offset=q_pos, kv_len=kv_len_vec,
    )


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> Dict[str, jnp.ndarray]:
    hkv, hd = cfg.n_kv_heads, cfg.head_dim_
    return {
        "k": jnp.zeros((batch, hkv, max_len, hd), dtype),
        "v": jnp.zeros((batch, hkv, max_len, hd), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def cross_attention(
    params: Dict[str, Any],
    x: jnp.ndarray,            # (B, Tq, D) decoder states
    enc: jnp.ndarray,          # (B, Tk, D) encoder output
    cfg: ModelConfig,
    *,
    use_pallas: bool = False,
    interpret: bool = False,
    kv: Optional[Dict[str, jnp.ndarray]] = None,  # precomputed {"k","v"}
) -> jnp.ndarray:
    q = jnp.einsum("btd,dhk->bhtk", x, params["wq"].astype(x.dtype))
    if "bq" in params:
        q = q + params["bq"].astype(q.dtype)[None, :, None, :]
    if kv is not None:
        k, v = kv["k"].astype(x.dtype), kv["v"].astype(x.dtype)
    else:
        k = jnp.einsum("btd,dhk->bhtk", enc, params["wk"].astype(enc.dtype))
        v = jnp.einsum("btd,dhk->bhtk", enc, params["wv"].astype(enc.dtype))
        if "bk" in params:
            k = k + params["bk"].astype(k.dtype)[None, :, None, :]
            v = v + params["bv"].astype(v.dtype)[None, :, None, :]
    o = flash_attention(
        q, k, v, causal=False, use_pallas=use_pallas, interpret=interpret
    )
    return _out(params, o)
