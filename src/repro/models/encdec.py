"""Whisper-style encoder-decoder backbone (audio frontend stubbed).

Encoder: bidirectional attention over precomputed frame embeddings
(``input_specs`` supplies (B, 1500, D) — the conv frontend is a stub per the
assignment), sinusoidal positions, LayerNorm + GELU MLP + biases.
Decoder: causal self-attention (+ KV cache) and cross-attention whose K/V
are computed once from the encoder output and cached for decode.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import attention as attn_mod
from . import mlp as mlp_mod
from .common import ParamBuilder, sinusoidal_positions, stack_layer_axes, stack_layer_params, unzip_params
from .config import ModelConfig
from .transformer import _init_norm, _norm


def _init_enc_block(pb: ParamBuilder, cfg: ModelConfig) -> Dict[str, Any]:
    return {
        "norm1": _init_norm(pb, cfg),
        "attn": attn_mod.init_attention(pb, cfg),
        "norm2": _init_norm(pb, cfg),
        "mlp": mlp_mod.init_mlp(pb, cfg),
    }


def _init_dec_block(pb: ParamBuilder, cfg: ModelConfig) -> Dict[str, Any]:
    return {
        "norm1": _init_norm(pb, cfg),
        "self_attn": attn_mod.init_attention(pb, cfg),
        "norm_x": _init_norm(pb, cfg),
        "cross_attn": attn_mod.init_cross_attention(pb, cfg),
        "norm2": _init_norm(pb, cfg),
        "mlp": mlp_mod.init_mlp(pb, cfg),
    }


def init_encdec(key, cfg: ModelConfig):
    pb = ParamBuilder(key=key, param_dtype=jnp.dtype(cfg.param_dtype))
    top: Dict[str, Any] = {
        "embed": pb.normal((cfg.vocab, cfg.d_model), ("vocab", "embed"), stddev=0.02),
        "enc_final_norm": _init_norm(pb, cfg),
        "final_norm": _init_norm(pb, cfg),
    }
    enc = [unzip_params(_init_enc_block(pb, cfg))[0] for _ in range(cfg.n_enc_layers)]
    enc_axes = unzip_params(_init_enc_block(pb, cfg))[1]
    dec = [unzip_params(_init_dec_block(pb, cfg))[0] for _ in range(cfg.n_layers)]
    dec_axes = unzip_params(_init_dec_block(pb, cfg))[1]
    values, axes = unzip_params(top)
    values["encoder"] = stack_layer_params(enc)
    axes["encoder"] = stack_layer_axes(enc_axes)
    values["decoder"] = stack_layer_params(dec)
    axes["decoder"] = stack_layer_axes(dec_axes)
    return values, axes


def encode(params, frames: jnp.ndarray, cfg: ModelConfig, *,
           use_pallas=False, interpret=False, unroll=False) -> jnp.ndarray:
    """frames: (B, T_enc, D) stub embeddings -> encoder states."""
    cdt = jnp.dtype(cfg.compute_dtype)
    x = frames.astype(cdt) + sinusoidal_positions(frames.shape[1], cfg.d_model).astype(cdt)

    def layer(x, p):
        h = _norm(cfg, p["norm1"], x)
        y, _ = attn_mod.attention(
            p["attn"], h, cfg, causal=False, use_rope=False,
            use_pallas=use_pallas, interpret=interpret,
        )
        x = x + y
        h = _norm(cfg, p["norm2"], x)
        return x + mlp_mod.mlp(p["mlp"], h, cfg), None

    x, _ = jax.lax.scan(layer, x, params["encoder"], unroll=unroll)
    return _norm(cfg, params["enc_final_norm"], x)


def decode(
    params, tokens: jnp.ndarray, enc_out: jnp.ndarray, cfg: ModelConfig,
    caches: Optional[Dict[str, Any]] = None, *,
    use_pallas=False, interpret=False, unroll=False, last_only=False,
    cross_kv: Optional[Dict[str, jnp.ndarray]] = None,
) -> Tuple[jnp.ndarray, Optional[Dict[str, Any]]]:
    """``cross_kv``: {"k","v"} (L, B, Hkv, T_enc, hd) — per-layer cross-attn
    projections of the encoder output, computed once at prefill.  Without it
    every decode step re-projects the 1500-frame encoder states through
    every layer's wk/wv (the dominant decode waste for enc-dec models)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    x = jnp.take(params["embed"], tokens, axis=0).astype(cdt)

    def layer(carry, xs):
        x = carry
        if cross_kv is not None:
            p, c, ckv = xs
        else:
            p, c = xs
            ckv = None
        h = _norm(cfg, p["norm1"], x)
        y, c_new = attn_mod.attention(
            p["self_attn"], h, cfg, cache=c,
            use_pallas=use_pallas, interpret=interpret,
        )
        x = x + y
        h = _norm(cfg, p["norm_x"], x)
        x = x + attn_mod.cross_attention(
            p["cross_attn"], h, enc_out, cfg,
            use_pallas=use_pallas, interpret=interpret, kv=ckv,
        )
        h = _norm(cfg, p["norm2"], x)
        x = x + mlp_mod.mlp(p["mlp"], h, cfg)
        return x, c_new

    if caches is None:
        x, _ = jax.lax.scan(
            lambda c, p: (layer(c, (p, None))[0], None), x, params["decoder"],
            unroll=unroll,
        )
        new_caches = None
    else:
        xs = ((params["decoder"], caches, cross_kv) if cross_kv is not None
              else (params["decoder"], caches))
        x, new_caches = jax.lax.scan(layer, x, xs, unroll=unroll)
    x = _norm(cfg, params["final_norm"], x)
    if last_only:
        x = x[:, -1:]  # prefill fast path: head on the final position only
    logits = jnp.einsum("btd,vd->btv", x, params["embed"].astype(cdt))
    return logits.astype(jnp.float32), new_caches


def init_dec_caches(cfg: ModelConfig, batch: int, max_len: int, dtype):
    c = attn_mod.init_cache(cfg, batch, max_len, dtype)
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (cfg.n_layers,) + a.shape), c
    )


def compute_cross_kv(params, enc_out: jnp.ndarray, cfg: ModelConfig):
    """Per-layer cross-attn K/V of the encoder output: (L, B, Hkv, T_enc, hd)."""

    def one(_, p):
        k = jnp.einsum("btd,dhk->bhtk", enc_out,
                       p["cross_attn"]["wk"].astype(enc_out.dtype))
        v = jnp.einsum("btd,dhk->bhtk", enc_out,
                       p["cross_attn"]["wv"].astype(enc_out.dtype))
        if "bk" in p["cross_attn"]:
            k = k + p["cross_attn"]["bk"].astype(k.dtype)[None, :, None, :]
            v = v + p["cross_attn"]["bv"].astype(v.dtype)[None, :, None, :]
        return None, {"k": k, "v": v}

    _, kv = jax.lax.scan(one, None, params["decoder"])
    return kv
