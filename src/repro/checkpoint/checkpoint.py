"""Chunked, compressed, async checkpointing with reshard-on-restore.

Layout of one checkpoint directory (atomic via tmp-dir + rename):

  step_000123/
    index.msgpack      {path: {shape, dtype, file, raw_bytes}}  + metadata
                       + codec ('zstd' | 'zlib')
    <leaf files>.zst   compressed little-endian raw tensor bytes
                       (.zz when the zlib fallback codec wrote them)

``zstandard`` is optional: when the wheel is absent we fall back to stdlib
``zlib`` and record the codec in the index so either build can restore the
other's checkpoints (zstd-written checkpoints still need the wheel to read).

Restore accepts a tree of NamedShardings and ``device_put``s each leaf
directly into its (possibly different) target sharding, which is what the
elastic runtime uses to resume on a *smaller or larger* mesh.
"""

from __future__ import annotations

import concurrent.futures as cf
import os
import pathlib
import re
import shutil
import zlib
from typing import Any, Dict, Optional, Tuple

import jax
import msgpack
import numpy as np

try:
    import zstandard
except ImportError:  # optional dep; zlib fallback keeps checkpoints working
    zstandard = None

PyTree = Any

_LEAF_RE = re.compile(r"[^A-Za-z0-9_.-]+")

DEFAULT_CODEC = "zstd" if zstandard is not None else "zlib"

# Leaf-file suffix per codec, so external tools that trust the extension
# (zstd CLI, file-type scanners) are not lied to; restore goes by the
# index's ``file`` entries, never the suffix.
_CODEC_SUFFIX = {"zstd": ".zst", "zlib": ".zz"}


def _compress(data: bytes, codec: str) -> bytes:
    if codec == "zstd":
        # one compressor per call: zstandard contexts are NOT thread-safe
        # for concurrent compress() on the same object
        return zstandard.ZstdCompressor(level=3).compress(data)
    if codec == "zlib":
        return zlib.compress(data, 3)
    raise ValueError(f"unknown checkpoint codec {codec!r}")


def _decompress(data: bytes, codec: str, raw_bytes: int) -> bytes:
    if codec == "zstd":
        if zstandard is None:
            raise RuntimeError(
                "checkpoint was written with zstd but the 'zstandard' module "
                "is not installed; install it or re-save with the zlib codec"
            )
        return zstandard.ZstdDecompressor().decompress(
            data, max_output_size=raw_bytes
        )
    if codec == "zlib":
        return zlib.decompress(data)
    raise ValueError(f"unknown checkpoint codec {codec!r}")


def _np_dtype(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes

        return np.dtype(getattr(ml_dtypes, name))


def _flatten(tree: PyTree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        out[key] = leaf
    return out


def save(
    root: str | pathlib.Path,
    step: int,
    tree: PyTree,
    metadata: Optional[Dict] = None,
    keep_last: int = 3,
    threads: int = 4,
) -> pathlib.Path:
    """Synchronous chunked save; see AsyncCheckpointer for the async path."""
    root = pathlib.Path(root)
    final = root / f"step_{step:09d}"
    tmp = root / f".tmp_step_{step:09d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    leaves = _flatten(tree)
    index: Dict[str, Dict] = {}

    codec = DEFAULT_CODEC

    def write_one(item: Tuple[str, Any]):
        key, leaf = item
        arr = np.asarray(leaf)
        fname = _LEAF_RE.sub("_", key) + _CODEC_SUFFIX[codec]
        with open(tmp / fname, "wb") as f:
            f.write(_compress(np.ascontiguousarray(arr).tobytes(), codec))
        return key, {
            "shape": list(arr.shape),
            # str(dtype) ('bfloat16', 'float32', ...) survives ml_dtypes,
            # unlike dtype.str which is opaque ('<V2') for bf16
            "dtype": str(arr.dtype),
            "file": fname,
            "raw_bytes": int(arr.nbytes),
        }

    with cf.ThreadPoolExecutor(max_workers=threads) as ex:
        for key, entry in ex.map(write_one, leaves.items()):
            index[key] = entry
    with open(tmp / "index.msgpack", "wb") as f:
        f.write(msgpack.packb({"leaves": index, "step": step,
                               "codec": codec, "metadata": metadata or {}}))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(root, keep_last)
    return final


def _gc(root: pathlib.Path, keep_last: int):
    steps = sorted(p for p in root.glob("step_*") if p.is_dir())
    for p in steps[:-keep_last] if keep_last > 0 else []:
        shutil.rmtree(p, ignore_errors=True)


def latest_step(root: str | pathlib.Path) -> Optional[int]:
    root = pathlib.Path(root)
    steps = sorted(int(p.name.split("_")[1]) for p in root.glob("step_*"))
    return steps[-1] if steps else None


def restore(
    root: str | pathlib.Path,
    step: Optional[int],
    target: PyTree,
    shardings: Optional[PyTree] = None,
) -> Tuple[PyTree, Dict]:
    """Load into the structure of ``target`` (a tree of arrays or
    ShapeDtypeStructs). ``shardings``: matching tree of NamedShardings for
    reshard-on-restore; None -> host arrays."""
    root = pathlib.Path(root)
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {root}")
    d = root / f"step_{step:09d}"
    with open(d / "index.msgpack", "rb") as f:
        meta = msgpack.unpackb(f.read())
    index = meta["leaves"]
    codec = meta.get("codec", "zstd")  # pre-codec checkpoints were zstd-only
    # Fail up front with one actionable error when the recorded codec is not
    # decodable in this environment — not a per-leaf decode traceback.
    if codec == "zstd" and zstandard is None:
        raise RuntimeError(
            f"checkpoint {d} was written with the 'zstd' codec but the "
            "'zstandard' module is not installed in this environment; "
            "install the zstandard wheel or re-save the checkpoint from a "
            "build using the zlib codec"
        )
    if codec not in _CODEC_SUFFIX:
        raise RuntimeError(
            f"checkpoint {d} records unknown codec {codec!r}; this build "
            f"supports {sorted(_CODEC_SUFFIX)}"
        )

    leaves_t, treedef = jax.tree_util.tree_flatten(target)
    flat_target = _flatten(target)
    flat_shard = _flatten(shardings) if shardings is not None else {}
    out: Dict[str, Any] = {}
    for key, tgt in flat_target.items():
        entry = index.get(key)
        if entry is None:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        with open(d / entry["file"], "rb") as f:
            payload = f.read()
        try:
            raw = _decompress(payload, codec, entry["raw_bytes"])
        except Exception as e:
            raise RuntimeError(
                f"checkpoint leaf {key!r} ({d / entry['file']}) failed to "
                f"decode with the index-recorded codec {codec!r}: {e} — the "
                "file is corrupt or was written by a build with a different "
                "codec"
            ) from None
        arr = np.frombuffer(raw, dtype=_np_dtype(entry["dtype"])).reshape(entry["shape"])
        exp_shape = tuple(tgt.shape)
        if tuple(arr.shape) != exp_shape:
            raise ValueError(f"{key}: shape {arr.shape} != target {exp_shape}")
        sh = flat_shard.get(key)
        out[key] = jax.device_put(arr, sh) if sh is not None else arr
    # reassemble in target order
    ordered = [out[k] for k in flat_target.keys()]
    return treedef.unflatten(ordered), meta["metadata"]


class AsyncCheckpointer:
    """One background writer; ``wait()`` before the next save or at exit.
    Device arrays are fetched to host *synchronously* (cheap vs. the write)
    so training can mutate them immediately after ``save_async`` returns."""

    def __init__(self, root: str | pathlib.Path, keep_last: int = 3):
        self.root = pathlib.Path(root)
        self.keep_last = keep_last
        self._ex = cf.ThreadPoolExecutor(max_workers=1)
        self._pending: Optional[cf.Future] = None

    def save_async(self, step: int, tree: PyTree, metadata=None) -> None:
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        self._pending = self._ex.submit(
            save, self.root, step, host_tree, metadata, self.keep_last
        )

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.result()
            self._pending = None
