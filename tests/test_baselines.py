"""Tests for the uncoded and HCMM baselines."""

import jax
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import baselines, simulator, theory


def test_uncoded_allocation_sums_to_R():
    mu = np.array([1.0, 2.0, 4.0, 1.0])
    a = np.full(4, 0.5)
    for rule in ("mean", "mu"):
        r = baselines.uncoded_allocation(1000, mu, a, rule)
        assert r.sum() == 1000
        assert np.all(r >= 0)


def test_uncoded_mean_rule_inverse_to_mean():
    mu = np.array([1.0, 4.0])
    a = np.array([0.5, 0.5])
    r = baselines.uncoded_allocation(900, mu, a, "mean")
    # E[beta] = 1.5 vs 0.75 -> loads 1:2
    np.testing.assert_allclose(r, [300, 600])


def test_hcmm_u_star_solves_fixed_point():
    for mu_a in (0.1, 0.5, 1.0, 5.0):
        u = baselines._hcmm_u_star(mu_a)
        assert u > 0
        np.testing.assert_allclose(np.log1p(u + mu_a), u, atol=1e-8)


def test_hcmm_loads_overprovision():
    """HCMM must allocate > R total (redundancy) and give faster helpers more."""
    mu = np.array([1.0, 2.0, 4.0] * 10)
    a = np.full(30, 0.5)
    loads = baselines.hcmm_loads(2000, mu, a)
    assert loads.sum() > 2000
    by_mu = [loads[mu == m].mean() for m in (1.0, 2.0, 4.0)]
    assert by_mu[0] < by_mu[1] < by_mu[2]


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 500), R=st.integers(100, 2000))
def test_property_hcmm_loads_positive_and_bounded(seed, R):
    rng = np.random.default_rng(seed)
    n = 20
    mu = rng.choice([1.0, 2.0, 4.0], n)
    a = rng.choice([0.25, 0.5, 1.0], n)
    loads = baselines.hcmm_loads(R, mu, a)
    assert np.all(loads >= 0)
    assert R <= loads.sum() <= 3 * R  # sane redundancy factor


def test_run_uncoded_and_hcmm_return_finite_T():
    cfg = simulator.ScenarioConfig(N=20, scenario=2)
    u = baselines.run_uncoded(jax.random.PRNGKey(0), cfg, 500)
    h = baselines.run_hcmm(jax.random.PRNGKey(0), cfg, 500)
    assert np.isfinite(u["T"]) and u["T"] > 0
    assert np.isfinite(h["T"]) and h["T"] > 0
    # HCMM (straggler-tolerant) should not be slower than uncoded in Sc2
    assert h["T"] <= u["T"] * 1.2
