"""Suite-level setup.

* Puts ``src/`` on ``sys.path`` so the suite runs without PYTHONPATH=src.
* Installs the vendored deterministic hypothesis shim
  (``tests/_hypothesis_compat.py``) when the real ``hypothesis`` is absent —
  the CI container has no network, so the property-test modules must collect
  offline.
"""

import importlib.util
import pathlib
import sys

_ROOT = pathlib.Path(__file__).resolve().parent.parent
_SRC = str(_ROOT / "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

try:
    import hypothesis  # noqa: F401
except ImportError:
    _spec = importlib.util.spec_from_file_location(
        "hypothesis", pathlib.Path(__file__).parent / "_hypothesis_compat.py"
    )
    _mod = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_mod)
    sys.modules["hypothesis"] = _mod
    sys.modules["hypothesis.strategies"] = _mod.strategies
