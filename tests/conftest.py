"""Suite-level setup.

* Puts ``src/`` on ``sys.path`` so the suite runs without PYTHONPATH=src.
* Installs the vendored deterministic hypothesis shim
  (``tests/_hypothesis_compat.py``) when the real ``hypothesis`` is absent —
  the CI container has no network, so the property-test modules must collect
  offline.
"""

import gc
import importlib.util
import pathlib
import sys

import pytest

_ROOT = pathlib.Path(__file__).resolve().parent.parent
_SRC = str(_ROOT / "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_between_modules():
    """Bound the live XLA-executable count across one long suite run.

    The suite jit-compiles several hundred distinct programs; with the
    fleet tests added, the accumulated executables can segfault the XLA
    CPU compiler late in the run (seen deterministically at
    test_simulator_dynamics inside ``backend_compile``).  Dropping the
    compiled-function caches at module boundaries keeps the process far
    from the cliff; cross-module compile reuse is minor (each module's
    shapes/configs are its own), so the runtime cost is small."""
    yield
    gc.collect()
    import jax

    jax.clear_caches()


try:
    import hypothesis  # noqa: F401
except ImportError:
    _spec = importlib.util.spec_from_file_location(
        "hypothesis", pathlib.Path(__file__).parent / "_hypothesis_compat.py"
    )
    _mod = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_mod)
    sys.modules["hypothesis"] = _mod
    sys.modules["hypothesis.strategies"] = _mod.strategies
