"""The explicit shard_map MoE must match the dense/gather MoE exactly when
capacity is non-binding (8 host devices, 4x2 and 2x4 meshes)."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + \
        " --xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.configs import get_config
    from repro.launch.mesh import make_host_mesh
    from repro.models.common import ParamBuilder
    from repro.models import moe as moe_mod
    from repro.models.moe_a2a import moe_block_a2a

    out = {}
    for (dn, mn) in ((4, 2), (2, 4)):
        cfg = get_config("qwen3-moe-235b-a22b", smoke=True)  # 8e top-2 cf=8
        pb = ParamBuilder(key=jax.random.PRNGKey(0))
        from repro.models.common import unzip_params
        params, _ = unzip_params(moe_mod.init_moe(pb, cfg))
        x = jax.random.normal(jax.random.PRNGKey(1), (dn * 2, 6, cfg.d_model),
                              jnp.float32) * 0.5
        ref, aux_ref = moe_mod.moe_block(params, x, cfg)
        mesh = make_host_mesh(data=dn, model=mn)
        with mesh:
            got, aux = jax.jit(
                lambda p, xx: moe_block_a2a(p, xx, cfg, mesh)
            )(params, x)
        key = f"{dn}x{mn}"
        out[f"err_{key}"] = float(jnp.abs(got - ref).max())
        out[f"aux_err_{key}"] = abs(float(aux) - float(aux_ref))
    print("RESULT:" + json.dumps(out))
    """
)


@pytest.mark.slow
def test_moe_a2a_matches_dense():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        env=env, cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=1200,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT:")][0]
    out = json.loads(line[len("RESULT:"):])
    for k, v in out.items():
        if k.startswith("err_"):
            assert v < 2e-5, (k, v, out)
        else:
            assert v < 1e-4, (k, v, out)
