"""Tests for the serving engine + CCP dispatcher."""

import time

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.runtime.serve_loop import CCPDispatcher, ServeEngine


@pytest.fixture(scope="module")
def engine():
    cfg = get_config("mistral-nemo-12b", smoke=True)
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    return ServeEngine(model, params, max_len=48), cfg


def test_generate_shapes_and_determinism(engine):
    eng, cfg = engine
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab, size=(3, 8)).astype(np.int32)
    out1 = eng.generate(prompts, n_new=6)
    out2 = eng.generate(prompts, n_new=6)
    assert out1.shape == (3, 6)
    np.testing.assert_array_equal(out1, out2)
    assert out1.min() >= 0 and out1.max() < cfg.vocab


def test_generate_matches_forward_argmax(engine):
    """First generated token == argmax of the full forward pass."""
    eng, cfg = engine
    prompts = np.random.default_rng(1).integers(
        0, cfg.vocab, size=(2, 8)).astype(np.int32)
    out = eng.generate(prompts, n_new=1)
    import jax.numpy as jnp

    logits = eng.model.forward(eng.params, jnp.asarray(prompts))
    expect = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
    np.testing.assert_array_equal(out[:, 0], expect)


def test_dispatcher_shifts_load_to_fast_replica(engine):
    eng, cfg = engine
    rng = np.random.default_rng(2)
    batches = [rng.integers(0, cfg.vocab, size=(2, 8)).astype(np.int32)
               for _ in range(16)]

    def fast(b):
        return eng.generate(b, n_new=2)

    def slow(b):
        time.sleep(0.05)
        return eng.generate(b, n_new=2)

    disp = CCPDispatcher([fast, slow])
    results, allocs = disp.run(batches)
    assert all(r is not None and r.shape == (2, 2) for r in results)
    if len(allocs) >= 2:
        last = allocs[-1]
        assert last[0] >= last[1], f"fast replica must get >= share: {allocs}"
