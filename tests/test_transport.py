"""Transport-layer tests: RTT processes, observation delay, TFRC pacing.

The load-bearing guarantee is **RTT=0 transparency**: enabling the
transport layer with ``rtt_mean = 0`` must be bit-for-bit the engine
without it, for every registered policy, on the static and churn paths,
single-task and fleet.  The transport tables are drawn from a folded key
(``fold_in(key, 0x577)``) so enabling them never perturbs the existing
churn draws — that, plus ``x + 0.0 == x`` in IEEE float32, is the whole
proof, and these tests pin it.

On top of that: the delayed-observation property (open-loop policies are
*bitwise invariant* under any RTT; ground-truth certification never
changes), golden replay of the PR-2 goldens through the transport-enabled
scan, tfrc_ccp == ccp at zero loss, and unit tests of the RTT draw /
delay / TFRC equation kernels.
"""

import dataclasses
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine, policies, simulator
from repro.core import transport

pytestmark = pytest.mark.transport

ENG = engine.Engine()

GOLDEN = json.loads(
    (pathlib.Path(__file__).parent / "golden" / "policy_equivalence.json")
    .read_text()
)

# Fields that must agree bitwise between transport-off and rtt0 runs.
SPINE_FIELDS = ("T", "efficiency", "r_n", "valid", "max_backoff",
                "lost_frac")

# A churn mix exercising every loss process the ACK path composes with
# (iid drop, GE bursts, outages, cell events).
CHURN = simulator.ChurnConfig(
    period=5.0, p_down=0.1, p_slow=0.2, drop_prob=0.05,
    ge_p_bad=0.03, ge_p_good=0.25, ge_loss_bad=0.5,
    p_cell=0.05, cell_frac=0.5, max_backoff=8.0)

# Policies whose pacing never reads tr_ok / rtt_ack / decoder feedback —
# delayed observation cannot change a single bit of their runs.
OPEN_LOOP = ("best", "uncoded_mean", "uncoded_mu", "hcmm")


def _bitwise(a, b):
    a, b = np.asarray(a), np.asarray(b)
    if a.dtype.kind == "f":
        return np.array_equal(a, b, equal_nan=True)
    return np.array_equal(a, b)


def _with_rtt(ch, **kw):
    base = dict(rtt_dist="fixed", rtt_mean=0.0)
    base.update(kw)
    return dataclasses.replace(ch, **base)


# ---------------------------------------------------------------------------
# Config surface
# ---------------------------------------------------------------------------

def test_churn_config_validates_rtt_fields():
    with pytest.raises(ValueError, match="rtt_dist"):
        simulator.ChurnConfig(rtt_dist="gaussian")
    with pytest.raises(ValueError, match="rtt_mean"):
        simulator.ChurnConfig(rtt_dist="fixed", rtt_mean=-1.0)
    with pytest.raises(ValueError, match="rtt_het"):
        simulator.ChurnConfig(rtt_dist="fixed", rtt_mean=1.0, rtt_het=1.5)


def test_rtt_enabled_and_neutral():
    assert not simulator.ChurnConfig().rtt_enabled
    ch = simulator.ChurnConfig(rtt_dist="fixed", rtt_mean=1.0)
    assert ch.rtt_enabled
    # transport with a real delay breaks neutrality (the engine must take
    # the churn path), but rtt_mean=0 transport keeps a neutral cfg neutral
    assert not ch.neutral
    assert simulator.ChurnConfig(rtt_dist="fixed", rtt_mean=0.0).neutral


def test_static_key_carries_rtt_dist():
    a = simulator.ChurnConfig().static_key()
    b = simulator.ChurnConfig(rtt_dist="cell", rtt_mean=1.0).static_key()
    assert len(a) == 6 and len(b) == 6
    assert a[-1] == "off" and b[-1] == "cell"


# ---------------------------------------------------------------------------
# RTT draw / observation-delay kernels
# ---------------------------------------------------------------------------

def test_draw_rtt_tables_shapes_and_regimes():
    key = jax.random.PRNGKey(0)
    N, M = 12, 64
    fixed = transport.draw_rtt_tables(
        key, simulator.ChurnConfig(rtt_dist="fixed", rtt_mean=2.0), N, M)
    assert fixed["rtt_base"].shape == (N,)
    assert fixed["rtt_jit"].shape == (N, M)
    assert fixed["ack_u"].shape == (N, M)
    assert np.allclose(fixed["rtt_base"], 2.0)  # rtt_het=0 -> exactly mean
    assert np.all(np.asarray(fixed["rtt_jit"]) == 1.0)

    het = transport.draw_rtt_tables(
        key, simulator.ChurnConfig(rtt_dist="fixed", rtt_mean=2.0,
                                   rtt_het=0.5), N, M)
    base = np.asarray(het["rtt_base"])
    assert base.min() >= 1.0 - 1e-6 and base.max() <= 3.0 + 1e-6
    assert base.std() > 0.0

    logn = transport.draw_rtt_tables(
        key, simulator.ChurnConfig(rtt_dist="lognormal", rtt_mean=2.0,
                                   rtt_sigma=0.5), N, 4096)
    jit = np.asarray(logn["rtt_jit"])
    assert jit.min() > 0.0
    assert abs(jit.mean() - 1.0) < 0.05  # unit-mean jitter

    cell = transport.draw_rtt_tables(
        key, simulator.ChurnConfig(rtt_dist="cell", rtt_mean=1.0,
                                   rtt_spike_prob=0.25,
                                   rtt_spike_scale=10.0), N, 4096)
    vals = np.unique(np.asarray(cell["rtt_jit"]))
    assert set(vals.tolist()) <= {1.0, 10.0}
    frac = (np.asarray(cell["rtt_jit"]) == 10.0).mean()
    assert 0.2 < frac < 0.3


def test_observation_delay_iid_and_ge():
    rtt = jnp.full((4,), 2.0)
    u = jnp.array([0.01, 0.9, 0.04, 0.5])
    # iid only: ack lost iff u < p_drop
    d = transport.observation_delay(rtt, u, 0.05)
    assert _bitwise(d, [4.0, 2.0, 4.0, 2.0])
    # GE bad state raises the ACK loss prob to the composed rate
    ge_params = (0.0, 0.0, jnp.float32(0.0), jnp.float32(0.9))
    d = transport.observation_delay(
        rtt, u, 0.05, ge_bad=jnp.array([True, True, False, False]),
        ge_params=ge_params)
    # bad: p = .05+.9-.045=0.905 -> u<p for 0.01 and 0.9 -> both lost
    assert _bitwise(d, [4.0, 4.0, 4.0, 2.0])
    # zero RTT: delay is exactly 0.0 whatever the loss outcome
    assert _bitwise(
        transport.observation_delay(jnp.zeros(4), u, 0.5), np.zeros(4))


def test_tfrc_send_interval():
    # p=0 -> no floor; monotone in both p and rtt
    assert float(transport.tfrc_send_interval(0.0, 3.0)) == 0.0
    lo = float(transport.tfrc_send_interval(0.01, 1.0))
    hi = float(transport.tfrc_send_interval(0.1, 1.0))
    assert 0.0 < lo < hi
    assert float(transport.tfrc_send_interval(0.1, 2.0)) == pytest.approx(
        2.0 * hi, rel=1e-6)


def test_loss_event_update_collapses_within_rtt():
    p0 = jnp.zeros(1)
    start = jnp.full(1, -jnp.inf)
    t, f = jnp.array([True]), jnp.array([False])
    # first loss at tx=10: new event
    p1, s1 = transport.loss_event_update(
        p0, start, t, f, jnp.array([10.0]), jnp.array([2.0]), w=0.5)
    assert float(p1[0]) == pytest.approx(0.5) and float(s1[0]) == 10.0
    # second loss inside one RTT: same event, no bump
    p2, s2 = transport.loss_event_update(
        p1, s1, t, f, jnp.array([11.0]), jnp.array([2.0]), w=0.5)
    assert float(p2[0]) == float(p1[0]) and float(s2[0]) == 10.0
    # loss beyond one RTT: a new event bumps again
    p3, s3 = transport.loss_event_update(
        p2, s2, t, f, jnp.array([13.0]), jnp.array([2.0]), w=0.5)
    assert float(p3[0]) > float(p2[0]) and float(s3[0]) == 13.0
    # delivery decays toward zero
    p4, _ = transport.loss_event_update(
        p3, s3, f, jnp.array([True]), jnp.array([14.0]),
        jnp.array([2.0]), w=0.5)
    assert 0.0 < float(p4[0]) < float(p3[0])


# ---------------------------------------------------------------------------
# RTT=0 transparency: the central acceptance criterion
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(policies.names()))
def test_rtt0_bitwise_churn(name):
    """Transport enabled at rtt_mean=0 is the bit-identical engine, for
    every registered policy, on the full churn mix."""
    keys = simulator.batch_keys(4)
    cfg0 = simulator.ScenarioConfig(N=16, scenario=1, churn=CHURN)
    cfg1 = dataclasses.replace(cfg0, churn=_with_rtt(CHURN))
    r0 = ENG.run(cfg0, name, keys, 60)
    r1 = ENG.run(cfg1, name, keys, 60)
    assert r1.M == r0.M
    for f in SPINE_FIELDS:
        assert _bitwise(r0[f], r1[f]), (name, f)


@pytest.mark.parametrize("rtt_dist", ["fixed", "lognormal", "cell"])
def test_rtt0_bitwise_every_regime(rtt_dist):
    """rtt_mean=0 kills the delay whatever jitter regime multiplies it."""
    keys = simulator.batch_keys(3)
    cfg0 = simulator.ScenarioConfig(N=12, scenario=1, churn=CHURN)
    ch = _with_rtt(CHURN, rtt_dist=rtt_dist, rtt_mean=0.0, rtt_het=0.5)
    cfg1 = dataclasses.replace(cfg0, churn=ch)
    r0 = ENG.run(cfg0, "ccp", keys, 60)
    r1 = ENG.run(cfg1, "ccp", keys, 60)
    for f in SPINE_FIELDS:
        assert _bitwise(r0[f], r1[f]), f


@pytest.mark.parametrize("name", sorted(GOLDEN))
def test_golden_replay_through_transport_scan(name):
    """The PR-2 goldens replay bit-for-bit through the transport-enabled
    scan at rtt_mean=0 — the strongest no-regression statement we can
    make without re-running the pre-redesign code."""
    g = GOLDEN[name]
    if name.startswith("static_sc1"):
        cfg, mode = (simulator.ScenarioConfig(N=20, scenario=1),
                     name.split("_")[-1])
        ch = simulator.ChurnConfig()
    elif name.startswith("static_sc2"):
        cfg, mode = simulator.ScenarioConfig(N=20, scenario=2), "ccp"
        ch = simulator.ChurnConfig()
    else:
        ch = simulator.ChurnConfig(
            period=5.0, p_down=0.1, p_slow=0.2, drop_prob=0.05,
            ge_p_bad=0.02, ge_p_good=0.2, ge_loss_bad=0.5,
            p_cell=0.1, cell_frac=0.5, outage_dist="lognormal",
            outage_mean=4.0, outage_sigma=0.5, max_backoff=8.0)
        cfg, mode = (simulator.ScenarioConfig(N=16, scenario=1, churn=ch),
                     name[len("churn_"):])
    # rtt0 transport on a *neutral* base cfg keeps it neutral (static
    # path); on a churn cfg it threads the delay line at delay == 0.0.
    cfg = dataclasses.replace(cfg, churn=_with_rtt(ch))
    keys = simulator.batch_keys(g["reps"], seed0=g.get("seed0", 0))
    res = ENG.run(cfg, policies.get(mode), keys, g["R"], M_override=g["M"])
    assert _bitwise(np.float32(np.asarray(g["T"])), np.float32(res.T)), name
    assert _bitwise(np.asarray(g["r_n"]), res.r_n), name
    assert _bitwise(np.float32(np.asarray(g["efficiency"])),
                    np.float32(res.efficiency)), name
    assert _bitwise(np.asarray(g["valid"]), res.valid), name


# ---------------------------------------------------------------------------
# Delayed-observation properties at RTT > 0
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", OPEN_LOOP)
def test_open_loop_policies_invariant_under_delay(name):
    """Open-loop pacing (tx + beta / tx + d_up) never reads the observed
    feedback, so any RTT leaves their entire run — ground-truth T and
    certification included — bit-for-bit unchanged."""
    keys = simulator.batch_keys(4)
    cfg0 = simulator.ScenarioConfig(N=16, scenario=1, churn=CHURN)
    ch = _with_rtt(CHURN, rtt_dist="lognormal", rtt_mean=2.0, rtt_het=0.5)
    cfg1 = dataclasses.replace(cfg0, churn=ch)
    r0 = ENG.run(cfg0, name, keys, 60)
    r1 = ENG.run(cfg1, name, keys, 60)
    for f in SPINE_FIELDS:
        assert _bitwise(r0[f], r1[f]), (name, f)


@pytest.mark.parametrize("name", ["ccp", "naive_oracle", "rateless_ccp"])
def test_delay_slows_feedback_policies_but_stays_certified(name):
    """Feedback-paced policies *must* pay for late observations (strictly
    larger mean T), but ground truth stays exact: every rep remains
    certified and the physical completion is still extracted from the
    time-exact trace."""
    keys = simulator.batch_keys(4)
    cfg0 = simulator.ScenarioConfig(N=16, scenario=1, churn=CHURN)
    ch = _with_rtt(CHURN, rtt_dist="lognormal", rtt_mean=2.0)
    cfg1 = dataclasses.replace(cfg0, churn=ch)
    r0 = ENG.run(cfg0, name, keys, 60)
    r1 = ENG.run(cfg1, name, keys, 60)
    assert np.asarray(r0.valid).all() and np.asarray(r1.valid).all()
    assert np.asarray(r1.T).mean() > np.asarray(r0.T).mean()


def test_ack_loss_doubles_delay_under_pure_drop():
    """With fixed RTT and iid drop only, every observation delay is
    exactly rtt or 2*rtt (the NACK retransmission round)."""
    ch = simulator.ChurnConfig(drop_prob=0.3, rtt_dist="fixed",
                               rtt_mean=1.5)
    cfg = simulator.ScenarioConfig(N=8, scenario=1, churn=ch)
    dyn = simulator.draw_dynamics(jax.random.PRNGKey(7), cfg, 64)
    d = transport.observation_delay(
        dyn["rtt_base"][:, None] * dyn["rtt_jit"], dyn["ack_u"],
        dyn["ack_p_drop"])
    vals = np.unique(np.asarray(d))
    assert set(vals.tolist()) <= {1.5, 3.0}
    lost_frac = (np.asarray(d) == 3.0).mean()
    assert 0.2 < lost_frac < 0.4


# ---------------------------------------------------------------------------
# tfrc_ccp
# ---------------------------------------------------------------------------

def test_tfrc_registered():
    assert "tfrc_ccp" in policies.names()
    p = policies.get("tfrc_ccp")
    assert isinstance(p, policies.TFRCCCPPolicy)
    assert p == policies.get("tfrc_ccp") and hash(p) == hash(p)


@pytest.mark.parametrize("rtt_mean", [0.0, 2.0])
def test_tfrc_equals_ccp_at_zero_loss(rtt_mean):
    """No losses -> p_ev stays 0 -> the TFRC floor is tx itself and the
    backoff never engages: tfrc_ccp is bitwise ccp at any RTT."""
    ch = simulator.ChurnConfig(p_down=0.1, p_slow=0.2,
                               rtt_dist="fixed", rtt_mean=rtt_mean)
    cfg = simulator.ScenarioConfig(N=12, scenario=1, churn=ch)
    keys = simulator.batch_keys(4)
    r_ccp = ENG.run(cfg, "ccp", keys, 60)
    r_tfrc = ENG.run(cfg, "tfrc_ccp", keys, 60)
    for f in SPINE_FIELDS:
        assert _bitwise(r_ccp[f], r_tfrc[f]), f


def test_tfrc_measures_loss_events():
    """Under burst loss the summary's p_ev lands in (0, 1): the estimator
    is alive and bounded."""
    ch = simulator.ChurnConfig(
        period=10.0, ge_p_bad=0.08, ge_p_good=0.15, ge_loss_bad=0.95,
        rtt_dist="fixed", rtt_mean=1.0, max_backoff=8.0)
    cfg = simulator.ScenarioConfig(N=12, scenario=1, churn=ch)
    res = ENG.run(cfg, "tfrc_ccp", simulator.batch_keys(3), 80)
    p_ev = np.asarray(res.extras["p_ev"])
    assert p_ev.shape == (3, 12)
    assert p_ev.min() >= 0.0 and p_ev.max() <= 1.0
    assert p_ev.max() > 0.0


# ---------------------------------------------------------------------------
# Fleet path
# ---------------------------------------------------------------------------

@pytest.mark.fleet
@pytest.mark.parametrize("name", ["ccp", "tfrc_ccp", "rateless_ccp"])
def test_fleet_m1_equals_single_task_with_transport(name):
    """The fleet scan threads the same delay line: a 1-task fleet under
    transport churn is bitwise the dedicated engine (shared rtt_base,
    task-0 jitter — the same elementwise product)."""
    ch = _with_rtt(CHURN, rtt_dist="lognormal", rtt_mean=1.0, rtt_het=0.3)
    cfg = simulator.ScenarioConfig(N=8, scenario=1, churn=ch)
    keys = simulator.batch_keys(3)
    res1 = ENG.run(cfg, name, keys, 40)
    resf = ENG.run_fleet(cfg, name, keys, 40)
    for f in SPINE_FIELDS:
        a = np.asarray(res1[f])
        b = np.asarray(resf[f])
        if b.ndim > a.ndim:
            b = b[:, 0]
        assert _bitwise(a, b), (name, f)
