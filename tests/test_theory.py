"""Tests for the paper's closed-form theory (Theorems 1-3)."""

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import theory


def test_expected_underutilization_branches():
    mu = 2.0
    # RTT >= 1/mu branch: constant 1/(e*mu)
    big = theory.expected_underutilization(1.0, mu)
    np.testing.assert_allclose(big, 1.0 / (np.e * mu), rtol=1e-12)
    # RTT -> 0: idle vanishes (the congestion cap Tr-Tx feeds the helper)
    small = theory.expected_underutilization(0.0, mu)
    np.testing.assert_allclose(small, 0.0, atol=1e-12)
    # continuity at RTT = 1/mu
    at = theory.expected_underutilization(1.0 / mu - 1e-9, mu)
    np.testing.assert_allclose(at, big, rtol=1e-5)


def test_expected_underutilization_monotone_in_rtt():
    mu = 3.0
    rtts = np.linspace(0, 1.0 / mu, 50)
    vals = theory.expected_underutilization(rtts, mu)
    assert np.all(np.diff(vals) >= -1e-12)


def test_efficiency_paper_regime_matches_99_4pct():
    """Paper §6: R=8000, mu in {1,3,9}, a=1/mu -> average theoretical
    efficiency 99.4115%."""
    # RTT^data = Bx/C_up + Br/C_down ~ (8*8000 + 8)/15e6 ~ 4.3 ms
    rtt = (8.0 * 8000 + 8.0) / 15e6
    mus = np.array([1.0, 3.0, 9.0])
    g = theory.efficiency(rtt, 1.0 / mus, mus)
    assert np.all(g > 0.98)
    np.testing.assert_allclose(g.mean(), 0.994115, atol=0.002)


def test_t_opt_model1_example():
    # single helper: T = (R+K) * E[beta]
    t = theory.t_opt_model1(100, 0, np.array([0.5]), np.array([2.0]))
    np.testing.assert_allclose(t, 100 * 1.0)


def test_t_opt_model2_jensen():
    """Realized (29) averaged over draws <= upper bound (30) (Jensen)."""
    rng = np.random.default_rng(0)
    a = np.full(50, 0.5)
    mu = rng.choice([1.0, 2.0, 4.0], 50)
    reps = []
    for _ in range(300):
        beta = a + rng.exponential(1.0 / mu)
        reps.append(theory.t_opt_model2_realized(1000, 50, beta))
    assert np.mean(reps) <= theory.t_opt_model2_upper(1000, 50, a, mu) * 1.01


@settings(max_examples=50, deadline=None)
@given(
    n=st.integers(2, 30),
    total=st.integers(1, 500),
    seed=st.integers(0, 10_000),
)
def test_property_largest_remainder_rounding(n, total, seed):
    rng = np.random.default_rng(seed)
    w = rng.random(n) + 1e-3
    loads = total * w / w.sum()
    r = theory.largest_remainder_round(loads, total)
    assert r.sum() == total
    assert np.all(r >= 0)
    assert np.all(np.abs(r - loads) <= 1.0 + 1e-9)


@settings(max_examples=30, deadline=None)
@given(n=st.integers(1, 64), seed=st.integers(0, 1000))
def test_property_optimal_allocation_sums_and_inverse_prop(n, seed):
    rng = np.random.default_rng(seed)
    e_beta = rng.uniform(0.1, 5.0, n)
    r = theory.optimal_allocation(1000, 50, e_beta)
    np.testing.assert_allclose(r.sum(), 1050, rtol=1e-9)
    # slower helpers receive fewer packets
    order = np.argsort(e_beta)
    assert np.all(np.diff(r[order]) <= 1e-9)
