"""Decoder-in-the-loop tests.

Pins the four contracts of the new ``repro.core.decode`` subsystem:

  (a) the incremental scan-safe peeling decoder (absorb/peel fixpoint) is
      *bit-identical* to the offline planner — same recovered set as the
      peeling closure on every tested (code, loss pattern, arrival order),
      including decode-failure (insufficient overhead) cases;
  (b) ``decode_completion`` (binary search over the time-sorted arrival
      prefix) equals the brute-force one-arrival-at-a-time replay;
  (c) the ``lt_decode`` payload kernel (round-levelized masked gather +
      subtract) matches its jnp reference and the offline
      ``fountain.decode``;
  (d) the engine integration: ``rateless_ccp`` keeps CCP's pacing
      bit-for-bit while completing at measured decode success (overhead
      within the robust-soliton bound from ``decode_failure_prob``),
      ``adaptive_rate_fb`` stops sending on decode feedback and never loses
      to fixed-K rateless CCP on the fig_churn regimes, and the block-policy
      ``horizon_hint`` cuts the scan horizon without changing results.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import decode, engine, fountain, policies, simulator
from repro.kernels.lt_decode import lt_decode, lt_decode_code
from repro.kernels.lt_encode import lt_encode_code

ENG = engine.Engine()


def _closure_ref(code, keep):
    """Pure-python peeling closure (the fixpoint both decoders must hit)."""
    known: set = set()
    nbrs = [set(code.idx[b, code.mask[b]].tolist()) for b in keep]
    changed = True
    while changed:
        changed = False
        for s in nbrs:
            rem = s - known
            if len(rem) == 1:
                known.add(rem.pop())
                changed = True
    return known


# ---------------------------------------------------------------------------
# (a) incremental absorb/peel == offline planner (property)
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    R=st.integers(min_value=8, max_value=40),
    k_frac=st.floats(min_value=0.5, max_value=1.5),
    seed=st.integers(min_value=0, max_value=500),
    data=st.data(),
)
def test_property_incremental_matches_offline_closure(R, k_frac, seed, data):
    """Any loss pattern, absorbed in any order and any batch size, must land
    on exactly the offline peeling closure: done iff peel_decode_plan
    succeeds, recovered mask == the closure set even on a stall."""
    K = max(4, int(R * k_frac))
    code = decode.make_decoder_code(R, K, seed=seed, d_max=8)
    tables = decode.make_tables(code)
    n_lost = data.draw(st.integers(min_value=0,
                                   max_value=max(1, (R + K) // 3)))
    rng = np.random.default_rng(seed + 1)
    lost = rng.choice(R + K, size=n_lost, replace=False)
    keep = np.setdiff1d(np.arange(R + K), lost)
    order = rng.permutation(keep)
    state = decode.init_state(R, tables)
    chunk = data.draw(st.integers(min_value=1, max_value=5))
    for c0 in range(0, len(order), chunk):
        ids = jnp.asarray(order[c0:c0 + chunk])
        state = decode.absorb(state, tables, ids,
                              jnp.ones(ids.shape[0], bool))
    plan = fountain.peel_decode_plan(code, keep)
    assert bool(state["done"]) == (plan is not None)
    closure = _closure_ref(code, keep)
    assert set(np.flatnonzero(np.asarray(state["recovered"]))) == closure
    assert int(state["count"]) == len(closure)


def test_absorb_ignores_unreceived_and_duplicates():
    R, K = 12, 16
    code = decode.make_decoder_code(R, K, seed=3, d_max=8)
    tables = decode.make_tables(code)
    state = decode.init_state(R, tables)
    ids = jnp.arange(8)
    # received=False lanes are non-events
    state = decode.absorb(state, tables, ids, jnp.zeros(8, bool))
    assert int(state["count"]) == 0 and not bool(state["rx"].any())
    # duplicates are idempotent
    state = decode.absorb(state, tables, ids, jnp.ones(8, bool))
    twice = decode.absorb(state, tables, ids, jnp.ones(8, bool))
    np.testing.assert_array_equal(np.asarray(state["recovered"]),
                                  np.asarray(twice["recovered"]))
    np.testing.assert_array_equal(np.asarray(state["res_deg"]),
                                  np.asarray(twice["res_deg"]))
    assert int(twice["ripple"]) == 0


def test_decode_failure_insufficient_overhead():
    """Losing a source covered by no received parity must stall, not lie."""
    code = fountain.make_lt_code(R=8, K=0, seed=0)
    tables = {"idx": jnp.zeros((1, 1), jnp.int32),
              "mask": jnp.zeros((1, 1), bool)}
    state = decode.init_state(8, tables)
    keep = np.setdiff1d(np.arange(8), [3])
    state = decode.absorb(state, tables, jnp.asarray(keep),
                          jnp.ones(keep.size, bool))
    assert not bool(state["done"]) and int(state["count"]) == 7
    assert fountain.peel_decode_plan(code, keep) is None


# ---------------------------------------------------------------------------
# (b) decode_completion == brute-force time-ordered replay
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("loss,seed", [(0.15, 0), (0.3, 1), (0.9, 2)])
def test_decode_completion_matches_bruteforce_replay(loss, seed):
    R, N, M = 16, 4, 20
    code = decode.make_decoder_code(R)          # pool P=64, N*M=80=R+P slots
    tables = decode.make_tables(code)
    rng = np.random.default_rng(seed)
    tr = rng.uniform(1.0, 100.0, (N, M))
    tr[rng.random((N, M)) < loss] = np.inf
    t, valid, k_star = decode.decode_completion(jnp.asarray(tr), tables, R)
    # brute force: absorb one arrival at a time in time order
    ids = (np.arange(M)[None, :] * N + np.arange(N)[:, None]).reshape(-1)
    flat = tr.reshape(-1)
    order = np.argsort(flat)
    state = decode.init_state(R, tables)
    bf_k, bf_t = None, np.inf
    for j, o in enumerate(order):
        if not np.isfinite(flat[o]):
            break
        state = decode.absorb(state, tables, jnp.asarray([ids[o]]),
                              jnp.asarray([True]))
        if bool(state["done"]):
            bf_k, bf_t = j + 1, flat[o]
            break
    if bf_k is None:
        assert not bool(valid) and not np.isfinite(float(t))
    else:
        assert int(k_star) == bf_k
        np.testing.assert_allclose(float(t), bf_t, rtol=1e-6)


# ---------------------------------------------------------------------------
# (c) lt_decode payload kernel == jnp reference == offline fountain.decode
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("R,K,bm,cols,bc,n_lost", [
    (12, 10, 4, 24, 8, 3),
    (20, 24, 8, 16, 16, 6),
    (8, 8, 16, 40, 8, 2),      # cols not divisible by bc -> padded path
])
def test_lt_decode_kernel_vs_ref_vs_offline(R, K, bm, cols, bc, n_lost):
    code = decode.make_decoder_code(R, K, seed=R + K, d_max=8)
    x = jax.random.normal(jax.random.PRNGKey(R), (R * bm, cols))
    coded = lt_encode_code(x, code, bm=bm)
    rng = np.random.default_rng(n_lost)
    lost = rng.choice(R, size=n_lost, replace=False)  # lose systematic rows
    keep = np.setdiff1d(np.arange(R + K), lost)
    plan = fountain.peel_decode_plan(code, keep)
    assert plan is not None, "pool code must peel these small loss patterns"
    crx = coded.reshape(R + K, bm, cols)[keep].reshape(-1, cols)
    ref = lt_decode(crx, plan, bm=bm)
    ker = lt_decode(crx, plan, bm=bm, use_pallas=True, interpret=True, bc=bc)
    off, method = fountain.decode(
        crx.reshape(len(keep), bm, cols), code, keep)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(x),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)
    assert method == "peel"
    np.testing.assert_allclose(np.asarray(off).reshape(-1, cols),
                               np.asarray(ref), atol=1e-4, rtol=1e-4)


def test_plan_rounds_levelization_is_consistent():
    """Every peeled source appears in exactly one round and only depends on
    direct sources or earlier rounds."""
    code = decode.make_decoder_code(24, 30, seed=9, d_max=8)
    keep = np.setdiff1d(np.arange(54), [1, 5, 8, 13, 21])
    plan = fountain.peel_decode_plan(code, keep)
    assert plan is not None
    rounds = fountain.plan_rounds(plan)
    seen = set(plan.direct_src.tolist())
    all_round_src = []
    for rnd in rounds:
        for t in range(rnd.size):
            nbrs = rnd.nbr_idx[t][rnd.nbr_coef[t] != 0]
            assert set(nbrs.tolist()) <= seen, "forward dependency"
        seen |= set(rnd.src.tolist())
        all_round_src.extend(rnd.src.tolist())
    assert sorted(all_round_src) == sorted(plan.order_src.tolist())


def test_lt_decode_code_raises_on_stall():
    code = fountain.make_lt_code(R=8, K=0, seed=0)
    crx = jnp.zeros((7 * 2, 4))
    keep = np.setdiff1d(np.arange(8), [3])
    with pytest.raises(ValueError, match="stalled"):
        lt_decode_code(crx, code, keep, bm=2)


# ---------------------------------------------------------------------------
# (d) engine integration
# ---------------------------------------------------------------------------

def test_rateless_pacing_equals_ccp_and_reports_decode_state():
    """rateless_ccp is Algorithm 1 bit-for-bit on the wire (same tx/tr
    traces) — only the completion rule changes — and surfaces the in-scan
    decoder state through RunResult extras."""
    cfg = simulator.ScenarioConfig(N=10, scenario=1)
    R, M = 200, 256
    key = jax.random.PRNGKey(0)
    k_h, k_p = jax.random.split(key)
    mu, a, rate = simulator.draw_helpers(k_h, cfg)
    beta, d_up, d_ack, d_down = simulator.draw_packet_tables(
        k_p, cfg, mu, a, rate, M, R)
    c = cfg.ccp_cfg(R)
    cfg_static = (c.Bx, c.Br, c.Back, c.alpha)
    outs = {}
    for mode in ("ccp", "rateless_ccp"):
        pol = policies.get(mode)
        aux = pol.prepare(cfg, R, c, mu, a, rate)
        outs[mode], _ = engine.policy_stream(
            beta, d_up, d_ack, d_down, policy=pol, cfg_static=cfg_static,
            aux=aux)
    for k in ("tx", "tr", "arrive", "idle"):
        np.testing.assert_array_equal(np.asarray(outs["ccp"][k]),
                                      np.asarray(outs["rateless_ccp"][k]), k)
    res = ENG.run(cfg, "rateless_ccp", simulator.batch_keys(2), R)
    assert bool(res.valid.all())
    assert (res.extras["dec_count"] == R).all()
    assert res.extras["dec_done"].all()
    # measured LT overhead: arrivals the decode consumed beyond R
    overhead = res.r_n.sum(axis=1) - R
    assert (overhead >= 0).all()


def test_rateless_overhead_within_robust_soliton_bound():
    """The acceptance anchor: the measured mean LT overhead must sit inside
    what the robust-soliton failure statistics say the code *needs* — the
    smallest K whose offline decode_failure_prob stall rate drops below 1/2
    at the matching loss level — and track the offline arrival-order
    Monte-Carlo of the same pool code."""
    R, p = 400, 0.1
    cfg = simulator.ScenarioConfig(
        N=20, scenario=1, mu_choices=(2.0,),
        churn=simulator.ChurnConfig(drop_prob=p, max_backoff=8.0))
    res = ENG.run(cfg, "rateless_ccp", simulator.batch_keys(6), R)
    assert bool(res.valid.all())
    overhead = res.r_n.sum(axis=1) - R
    assert (overhead >= 0).all()
    mean_ov = float(overhead.mean())
    # robust-soliton bound from decode_failure_prob: the K the generic code
    # needs before peeling survives this loss rate half the time
    k_bound = None
    for K in (R // 8, R // 4, R // 2, R):
        n_lost = int(np.ceil(p * (R + K)))
        stats = fountain.decode_failure_prob(R, K, n_lost, trials=12, seed=0)
        if stats["peel_stall"] <= 0.5:
            k_bound = K
            break
    assert k_bound is not None
    assert mean_ov <= k_bound, (mean_ov, k_bound)
    # and the in-engine measurement tracks the offline arrival-order MC of
    # the very same pool code
    offline = decode.offline_overhead_samples(
        R, decode.make_decoder_code(R), p, trials=8, seed=3)
    ok = offline[offline >= 0]
    assert ok.size > 0
    assert mean_ov / R <= (ok.mean() / R) * 1.5 + 0.05, (mean_ov, ok.mean())


def test_adaptive_fb_stops_sending_after_decode_time():
    """Once decode_done fires and the send clock passes decode_t_done, the
    stream stops for good (tx trace goes +inf) — the realized overhead
    sheds to what the decode needed."""
    R = 200
    cfg = simulator.ScenarioConfig(
        N=10, scenario=1,
        churn=simulator.ChurnConfig(drop_prob=0.1, max_backoff=8.0))
    key = jax.random.PRNGKey(1)
    k_h, k_p = jax.random.split(key)
    mu, a, rate = simulator.draw_helpers(k_h, cfg)
    M = 4 * (R + cfg.K(R))
    beta, d_up, d_ack, d_down = simulator.draw_packet_tables(
        k_p, cfg, mu, a, rate, M, R)
    dyn = simulator.draw_dynamics(jax.random.fold_in(key, 0xC0DE), cfg, M)
    c = cfg.ccp_cfg(R)
    pol = policies.get("adaptive_rate_fb")
    aux = pol.prepare(cfg, R, c, mu, a, rate)
    outs, psum = engine.policy_stream(
        beta, d_up, d_ack, d_down, policy=pol,
        cfg_static=(c.Bx, c.Br, c.Back, c.alpha),
        churn_static=cfg.churn.static_key(), dyn=dyn, a=a, aux=aux)
    tx = np.asarray(outs["tx"])
    assert np.isinf(tx).any(), "stream must stop after decode success"
    # stopping is permanent per helper
    for n in range(tx.shape[0]):
        stopped = np.isinf(tx[n])
        if stopped.any():
            assert stopped[stopped.argmax():].all()
    # and the unsent slots are non-events in the trace
    assert not np.asarray(outs["lost"])[np.isinf(tx)].any()
    assert (np.asarray(outs["idle"])[np.isinf(tx)] == 0).all()


def test_adaptive_fb_not_worse_than_fixed_k_rateless_on_churn_regimes():
    """The like-for-like acceptance comparison (both policies complete at
    measured decode success): closing the loop — adapted send overhead +
    stop-on-decode — must not lose to fixed-K rateless CCP on any fig_churn
    regime endpoint."""
    from benchmarks import fig_churn

    keys = simulator.batch_keys(8)
    R, n = 200, 20
    for name, (axis, mk_cfg, _ax) in fig_churn.SWEEPS.items():
        cfg = mk_cfg(axis[-1], n)
        rl = ENG.run(cfg, "rateless_ccp", keys, R)
        fb = ENG.run(cfg, "adaptive_rate_fb", keys, R)
        both = rl.valid & fb.valid
        assert both.sum() >= 4, (name, rl.valid, fb.valid)
        ratio = float(fb.T[both].mean() / rl.T[both].mean())
        assert ratio <= 1.02, (name, ratio)


# ---------------------------------------------------------------------------
# horizon_hint: block policies run a ~R/N-packet scan, results unchanged
# ---------------------------------------------------------------------------

def test_horizon_hint_cuts_engine_M_for_block_policies():
    cfg = simulator.ScenarioConfig(N=10, scenario=1, mu_choices=(2.0,))
    R = 320
    keys = simulator.batch_keys(3)
    default_m = simulator._horizon_shared(cfg, R)
    for pol in ("uncoded_mean", "hcmm"):
        res = ENG.run(cfg, pol, keys, R)
        assert res.M < default_m, (pol, res.M, default_m)
        assert bool(res.valid.all())
        # the allocation is horizon-independent: identical at the old M
        big = ENG.run(cfg, pol, keys, R, M_override=default_m)
        np.testing.assert_array_equal(res.extras["loads"],
                                      big.extras["loads"])
        assert bool(big.valid.all())
    # CCP keeps the engine default — no hint
    assert policies.get("ccp").horizon_hint(cfg, R, R + cfg.K(R)) is None


def test_block_policy_results_pinned_equal_at_both_horizons():
    """The property that justifies the hint, pinned bit-for-bit: a block
    policy's stream is causal in the packet index and reads only the first
    ``loads_n`` packets, so truncating the *same* packet tables to the
    hinted horizon changes nothing — neither the trace prefix nor T."""
    cfg = simulator.ScenarioConfig(N=10, scenario=1, mu_choices=(2.0,))
    R, M_big = 320, 512
    kk = R + cfg.K(R)
    pol = policies.get("uncoded_mean")
    h = pol.horizon_hint(cfg, R, kk)
    assert h is not None and h < M_big
    key = jax.random.PRNGKey(2)
    k_h, k_p = jax.random.split(key)
    mu, a, rate = simulator.draw_helpers(k_h, cfg)
    beta, d_up, d_ack, d_down = simulator.draw_packet_tables(
        k_p, cfg, mu, a, rate, M_big, R)
    c = cfg.ccp_cfg(R)
    aux = pol.prepare(cfg, R, c, mu, a, rate)
    assert int(jnp.max(aux["loads"])) <= h
    cfg_static = (c.Bx, c.Br, c.Back, c.alpha)
    big, _ = engine.policy_stream(beta, d_up, d_ack, d_down, policy=pol,
                                  cfg_static=cfg_static, aux=aux)
    small, _ = engine.policy_stream(
        beta[:, :h], d_up[:, :h], d_ack[:, :h], d_down[:, :h], policy=pol,
        cfg_static=cfg_static, aux=aux)
    np.testing.assert_array_equal(np.asarray(big["tr"][:, :h]),
                                  np.asarray(small["tr"]))
    t_big, v_big = pol.finalize(big, aux, cfg, R, kk, None)
    t_small, v_small = pol.finalize(small, aux, cfg, R, kk, None)
    assert bool(v_big) and bool(v_small)
    np.testing.assert_array_equal(np.float32(t_big), np.float32(t_small))


# ---------------------------------------------------------------------------
# (g) decoder-aware symbol scheduling: ids follow send time (PR 7)
# ---------------------------------------------------------------------------

def test_send_time_ids_round_robin_on_ties():
    """Simultaneous sends keep the legacy round-robin order (stable sort
    by helper index), so homogeneous lockstep traces are unchanged."""
    tx = jnp.zeros(5)
    ids, nxt = engine._send_time_ids(jnp.int32(0), tx, jnp.ones(5, bool))
    np.testing.assert_array_equal(np.asarray(ids), np.arange(5))
    assert int(nxt) == 5


def test_send_time_ids_follow_send_order_and_skip_unsent():
    """Earlier senders draw earlier symbols; stopped streams (tx = inf)
    consume nothing from the counter."""
    tx = jnp.asarray([3.0, 1.0, jnp.inf, 2.0])
    sent = jnp.isfinite(tx)
    ids, nxt = engine._send_time_ids(jnp.int32(10), tx, sent)
    ids = np.asarray(ids)
    assert ids[1] == 10 and ids[3] == 11 and ids[0] == 12  # send order
    assert int(nxt) == 13  # 3 sent -> counter advances by 3
    # the unsent slot's placeholder never collides with a consumed id
    assert ids[2] >= nxt or ids[2] not in (10, 11, 12)


def test_send_time_ids_counter_is_cumulative():
    tx = jnp.asarray([0.0, jnp.inf, 1.0])
    sent = jnp.isfinite(tx)
    _, n1 = engine._send_time_ids(jnp.int32(0), tx, sent)
    ids2, n2 = engine._send_time_ids(n1, tx + 5.0, sent)
    assert int(n1) == 2 and int(n2) == 4
    assert np.asarray(ids2)[np.asarray(sent)].min() == 2


def test_send_order_ids_tie_break_reproduces_grid_and_orders_by_time():
    """Lockstep (all-equal tx per round) must reproduce the legacy grid
    bit for bit; heterogeneous tx must rank strictly by send instant."""
    N, M = 4, 3
    lock = jnp.broadcast_to(jnp.arange(M, dtype=jnp.float32)[None, :], (N, M))
    grid = (jnp.arange(M)[None, :] * N + jnp.arange(N)[:, None])
    np.testing.assert_array_equal(
        np.asarray(decode.send_order_ids(lock)), np.asarray(grid))
    # helper 0 sends everything before helper 1 starts
    tx = jnp.asarray([[0.0, 1.0, 2.0], [10.0, 11.0, 12.0]])
    ids = np.asarray(decode.send_order_ids(tx))
    np.testing.assert_array_equal(ids, [[0, 1, 2], [3, 4, 5]])
    # unsent slots rank after every real send
    tx = jnp.asarray([[0.0, jnp.inf], [1.0, 2.0]])
    ids = np.asarray(decode.send_order_ids(tx))
    assert ids[0, 1] == 3 and sorted(ids.ravel()) == [0, 1, 2, 3]


def test_send_order_assignment_shrinks_decode_overhead_vs_round_robin():
    """The counter-gap improvement pinned (fig_decode's mechanism): under
    heterogeneous pacing the legacy grid ``g = i*N + n`` hands a
    straggler's late sends *early* pool ids — systematic symbols the
    decoder then stalls on — while the send-counter assignment keeps the
    ids on the wire a dense prefix of the pool's designed (cover) order.
    Completion must never be later and must strictly improve overall."""
    cfg = simulator.ScenarioConfig(N=10, scenario=2)  # wide mu spread
    R, M = 200, 256
    pol = policies.get("rateless_ccp")
    t_gap = 0.0
    for seed in (0, 1, 2):
        key = jax.random.PRNGKey(seed)
        k_h, k_p = jax.random.split(key)
        mu, a, rate = simulator.draw_helpers(k_h, cfg)
        beta, d_up, d_ack, d_down = simulator.draw_packet_tables(
            k_p, cfg, mu, a, rate, M, R)
        c = cfg.ccp_cfg(R)
        aux = pol.prepare(cfg, R, c, mu, a, rate)
        outs, _ = engine.policy_stream(
            beta, d_up, d_ack, d_down, policy=pol,
            cfg_static=(c.Bx, c.Br, c.Back, c.alpha), aux=aux)
        tables = aux["decoder"]["tables"]
        tr = outs["tr"]
        t_new, ok_new, k_new = decode.decode_completion(
            tr, tables, R, ids=decode.send_order_ids(outs["tx"]))
        t_old, ok_old, k_old = decode.decode_completion(tr, tables, R)
        assert bool(ok_new)
        if bool(ok_old):
            assert float(t_new) <= float(t_old) + 1e-6, seed
            assert int(k_new) <= int(k_old), seed
            t_gap += float(t_old) - float(t_new)
        else:
            t_gap += 1.0  # legacy assignment failed outright
    # not merely never-worse: the improvement must actually materialize
    assert t_gap > 0.0
