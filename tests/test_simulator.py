"""Integration tests: the discrete-event simulator reproduces the paper's
qualitative and quantitative claims (small-scale versions of Figs. 3-5)."""

import jax
import numpy as np
import pytest

from repro.core import baselines, engine, simulator, theory

ENG = engine.Engine()


def _run(mode):
    return lambda key, cfg, R: ENG.run_one(key, cfg, mode, R)


run_ccp = _run("ccp")
run_best = _run("best")
run_naive = _run("naive")


@pytest.fixture(scope="module")
def sc1():
    return simulator.ScenarioConfig(N=50, scenario=1)


@pytest.fixture(scope="module")
def sc2():
    return simulator.ScenarioConfig(N=50, scenario=2)


def _mean_over_reps(fn, cfg, R, reps=4, seed0=0):
    return float(np.mean([fn(jax.random.PRNGKey(seed0 + r), cfg, R)["T"] for r in range(reps)]))


def test_timeline_monotone_and_fifo(sc1):
    out = run_ccp(jax.random.PRNGKey(0), sc1, R=500)
    # completion certified
    assert out["T"] > 0
    # r_n splits the work: counts sum to >= R+K
    assert out["r_n"].sum() >= 500 + sc1.K(500)


def test_ccp_close_to_best_and_theory_sc1(sc1):
    R = 1000
    t_ccp = _mean_over_reps(run_ccp, sc1, R)
    t_best = _mean_over_reps(run_best, sc1, R)
    o = run_ccp(jax.random.PRNGKey(0), sc1, R)
    t_opt = theory.t_opt_model1(R, sc1.K(R), o["a"], o["mu"])
    # paper: CCP within a few percent of Best and Optimum-Analysis
    assert t_ccp <= t_best * 1.10
    assert abs(t_ccp - t_opt) / t_opt < 0.25  # helper draw noise at N=50


def test_ccp_beats_baselines_sc1(sc1):
    R = 1000
    t_ccp = _mean_over_reps(run_ccp, sc1, R)
    t_unc = _mean_over_reps(
        lambda k, c, R: baselines.run_uncoded(k, c, R, rule="mean"), sc1, R
    )
    t_hcmm = _mean_over_reps(baselines.run_hcmm, sc1, R)
    assert t_ccp < t_unc, "CCP must beat uncoded (paper Fig 3a)"
    assert t_ccp < t_hcmm, "CCP must beat HCMM (paper Fig 3a)"


def test_ccp_beats_baselines_sc2_with_big_margin(sc2):
    R = 1000
    t_ccp = _mean_over_reps(run_ccp, sc2, R)
    t_unc = _mean_over_reps(
        lambda k, c, R: baselines.run_uncoded(k, c, R, rule="mean"), sc2, R
    )
    t_hcmm = _mean_over_reps(baselines.run_hcmm, sc2, R)
    # paper Fig 3b: ~40% over HCMM, ~69% over uncoded
    assert (t_hcmm - t_ccp) / t_hcmm > 0.2
    assert (t_unc - t_ccp) / t_unc > 0.45
    # and HCMM beats uncoded in scenario 2 (it was designed for it)
    assert t_hcmm < t_unc


def test_efficiency_exceeds_99pct(sc1):
    out = run_ccp(jax.random.PRNGKey(3), sc1, R=2000)
    eff = np.nanmean(out["efficiency"])
    assert eff > 0.99, f"paper: ~99.7% efficiency, got {eff}"


def test_efficiency_close_to_theory(sc1):
    """Simulated efficiency should exceed the analytical average (12), which
    the paper notes is a (slightly loose) lower bound."""
    out = run_ccp(jax.random.PRNGKey(4), sc1, R=2000)
    # RTT^data per helper = Bx/C_up + Br/C_down ~ (Bx+Br)/rate
    rtt = (8.0 * 2000 + 8.0) / out["rate"]
    gamma = theory.efficiency(rtt, out["a"], out["mu"])
    assert np.nanmean(out["efficiency"]) > np.mean(gamma) - 0.01


def test_naive_gap_grows_with_R_on_slow_links():
    """Fig 5: with 0.1-0.2 Mbps links, T_naive - T_ccp grows with R while
    T_ccp - T_best stays flat."""
    cfg = simulator.ScenarioConfig(
        N=10, scenario=2, rate_lo=0.1e6, rate_hi=0.2e6
    )
    gaps_naive, gaps_best = [], []
    for R in (200, 800):
        t_ccp = _mean_over_reps(run_ccp, cfg, R, reps=3)
        t_naive = _mean_over_reps(run_naive, cfg, R, reps=3)
        t_best = _mean_over_reps(run_best, cfg, R, reps=3)
        gaps_naive.append(t_naive - t_ccp)
        gaps_best.append(t_ccp - t_best)
    assert gaps_naive[1] > gaps_naive[0], "naive gap must grow with R"
    assert gaps_naive[1] > 4 * gaps_best[1], "best gap must stay small"


def test_scenario2_t_opt_realized_close():
    cfg = simulator.ScenarioConfig(N=50, scenario=2)
    R = 1000
    t_ccp = _mean_over_reps(run_ccp, cfg, R, reps=4)
    ub = None
    o = run_ccp(jax.random.PRNGKey(0), cfg, R)
    ub = theory.t_opt_model2_upper(R, cfg.K(R), o["a"], o["mu"])
    assert t_ccp < ub * 1.15  # Thm 3: E[T_opt] <= ub; CCP tracks T_opt


def test_completion_time_certification():
    """If the horizon is too short the order statistic must be flagged."""
    import jax.numpy as jnp

    tr = jnp.array([[1.0, 2.0, 3.0], [10.0, 20.0, 30.0]])
    t, valid = simulator.completion_time(tr, 4)
    assert not bool(valid) or float(t) <= 3.0


def test_allocation_tracks_heterogeneity(sc1):
    """CCP's realized per-helper packet counts follow eq. (23): r_n
    proportional to 1/E[beta_n]."""
    out = run_ccp(jax.random.PRNGKey(5), sc1, R=4000)
    e_beta = out["a"] + 1.0 / out["mu"]
    pred = theory.optimal_allocation(4000, sc1.K(4000), e_beta)
    corr = np.corrcoef(pred, out["r_n"])[0, 1]
    assert corr > 0.97, f"allocation correlation {corr}"
