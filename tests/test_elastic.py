"""Integration: elastic shrink/restore + data pipeline + checkpointing,
run on 8 host devices in a subprocess (train -> fail 4 devices -> resume on
a smaller mesh from checkpoint -> loss continuity)."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + \
        " --xla_force_host_platform_device_count=8"
    import json, tempfile
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_config
    from repro.data import SyntheticLM
    from repro.launch.mesh import make_host_mesh
    from repro.models import build_model
    from repro.optim import adamw
    from repro.parallel import sharding as shd
    from repro.runtime.elastic import ElasticConfig, ElasticTrainer
    from repro.runtime.train_loop import make_train_step

    cfg = get_config("phi4-mini-3.8b", smoke=True)
    model = build_model(cfg)
    opt_cfg = adamw.AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=200,
                                weight_decay=0.0)
    GB, T = 8, 16
    data = SyntheticLM(cfg.vocab, T, GB, n_micro=1, seed=0)

    def build(mesh):
        rules = shd.make_rules(cfg, mesh)
        params, axes = model.init(jax.random.PRNGKey(0))
        p_sh = shd.param_shardings(mesh, axes, rules)
        params = jax.device_put(params, p_sh)
        opt = adamw.init(params)
        o_sh = adamw.AdamWState(
            step=NamedSharding(mesh, P()),
            m=shd.opt_state_shardings(mesh, axes, rules,
                                      jax.tree.map(lambda x: x.shape, params)),
            v=shd.opt_state_shardings(mesh, axes, rules,
                                      jax.tree.map(lambda x: x.shape, params)),
        )
        opt = jax.device_put(opt, o_sh)
        raw = make_train_step(model, opt_cfg, 1, pre_shaped=True)
        def step_fn(state, batch):
            p, o = state
            with mesh:
                p, o, metrics = jax.jit(raw)(p, o, batch)
            return (p, o), metrics
        return (params, opt), step_fn, (p_sh, o_sh)

    def batch_fn(step, mesh):
        return {k: jnp.asarray(v) for k, v in data.batch(step).items()}

    with tempfile.TemporaryDirectory() as d:
        cfg_e = ElasticConfig(ckpt_dir=d, ckpt_every=5)
        tr = ElasticTrainer(cfg_e, build)
        tr.rebuild(model_axis=2)            # 4x2 mesh over 8 devices
        losses_a = tr.run(12, batch_fn)     # ckpt at step 5, 10
        step_before = tr.step
        tr.fail_device(7, model_axis=2)     # lose a device: 7 alive -> 3x2 mesh
        step_restored = tr.step             # rolled back to the checkpoint
        losses_b = tr.run(8, batch_fn)
        out = {
            "losses_a": losses_a,
            "losses_b": losses_b,
            "resumed_step": step_before,
            "step_after_restore": step_restored,
            "mesh_shape": list(tr.mesh.devices.shape),
        }
    print("RESULT:" + json.dumps(out))
    """
)


@pytest.mark.slow
def test_elastic_failover_resume():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        env=env, cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=1200,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT:")][0]
    out = json.loads(line[len("RESULT:"):])
    # restored from the last checkpoint (step 10 <= 12)
    assert out["step_after_restore"] <= out["resumed_step"]
    assert out["step_after_restore"] >= 5
    # mesh shrank: fewer than 8 devices in use
    import numpy as np

    assert int(np.prod(out["mesh_shape"])) < 8
    # training continues sanely after restore (finite, roughly continuous)
    la, lb = out["losses_a"], out["losses_b"]
    assert all(x == x and x < 1e4 for x in lb)
    assert lb[0] < la[0] + 1.0, "post-restore loss must not blow up"
    # loss decreases over the whole run (learnable synthetic stream)
    assert lb[-1] < la[0]
