"""Fast-lane smoke of the benchmark harness: ``benchmarks.run --smoke``.

Runs the churn figure end-to-end at tiny scale (2 reps, R=200, N=20,
sweep endpoints only) in a subprocess, pointing BENCH_OUT_DIR at a tmpdir
so the committed full-scale artifacts are untouched, and checks the
artifact schema: the key-schedule meta marker, all three sweeps, all four
modes, and per-point invalid-rep counts (dropped, never averaged).
"""

import json
import os
import pathlib
import subprocess
import sys

_ROOT = pathlib.Path(__file__).resolve().parent.parent


def test_run_smoke_fig_churn(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env["BENCH_OUT_DIR"] = str(tmp_path)
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--smoke", "--shard",
         "--only", "fig_churn"],
        capture_output=True, text=True, env=env, cwd=_ROOT, timeout=900,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    csv = [l for l in proc.stdout.splitlines() if l.startswith("fig_churn,")]
    assert csv, proc.stdout

    doc = json.loads((tmp_path / "fig_churn.json").read_text())
    assert doc["meta"]["key_schedule"] == "fold_in"
    rows = doc["data"]
    assert {r["sweep"] for r in rows} == {"iid", "burst", "cell"}
    for r in rows:
        for mode in ("ccp", "best", "naive", "naive_oracle"):
            assert "invalid" in r[mode], r
            assert r[mode]["invalid"] + 1 > 0  # present and an int
    # the endpoints tell the adaptivity story even at smoke scale: the
    # static-timer Naive must degrade more than CCP on the loss sweeps
    by = {(r["sweep"], i): r for s in ("iid", "burst", "cell")
          for i, r in enumerate(rr for rr in rows if rr["sweep"] == s)}
    for sweep in ("iid", "burst"):
        lo, hi = by[(sweep, 0)], by[(sweep, 1)]
        ccp_deg = hi["ccp"]["mean"] / lo["ccp"]["mean"]
        naive_deg = hi["naive"]["mean"] / lo["naive"]["mean"]
        assert naive_deg > ccp_deg, (sweep, ccp_deg, naive_deg)
