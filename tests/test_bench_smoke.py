"""Fast-lane smoke of the benchmark harness: ``benchmarks.run --smoke``.

Runs the churn + decode figures end-to-end at tiny scale (2 reps, R=200,
sweep endpoints only) in a subprocess, pointing BENCH_OUT_DIR at a tmpdir
so the committed full-scale artifacts are untouched, and checks the
artifact schema: the key-schedule / policy / decoder meta markers, all
three churn sweeps, *every registered policy* — including the
decoder-in-the-loop ``rateless_ccp`` / ``adaptive_rate_fb`` (so a policy
that breaks under jit/vmap/shard fails this fast lane), the measured LT
overhead stats, and per-point invalid-rep counts (dropped, never
averaged).
"""

import json
import os
import pathlib
import subprocess
import sys

_ROOT = pathlib.Path(__file__).resolve().parent.parent


def test_run_smoke_fig_churn(tmp_path):
    from repro.core import policies

    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env["BENCH_OUT_DIR"] = str(tmp_path)
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--smoke", "--shard",
         "--only", "fig_churn,fig_decode"],
        capture_output=True, text=True, env=env, cwd=_ROOT, timeout=1800,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    csv = [l for l in proc.stdout.splitlines() if l.startswith("fig_churn,")]
    assert csv, proc.stdout

    doc = json.loads((tmp_path / "fig_churn.json").read_text())
    assert doc["meta"]["key_schedule"] == "fold_in"
    # the smoke lane sweeps every registered policy, recorded in the meta —
    # including the decoder-in-the-loop ones
    swept = doc["meta"]["policy"]
    assert set(swept) == set(policies.names())
    assert {"rateless_ccp", "adaptive_rate_fb"} <= set(swept)
    # meta.decoder marks the completion semantics per policy, so counter
    # and in-loop delay trajectories are never compared silently
    assert doc["meta"]["decoder"]["rateless_ccp"] == "in_loop"
    assert doc["meta"]["decoder"]["adaptive_rate_fb"] == "in_loop"
    assert doc["meta"]["decoder"]["ccp"] == "counter"
    rows = doc["data"]
    assert {r["sweep"] for r in rows} == {"iid", "burst", "cell"}
    for r in rows:
        for name in swept:
            assert "invalid" in r[name], (name, r)
            assert r[name]["invalid"] + 1 > 0  # present and an int
    # the endpoints tell the adaptivity story even at smoke scale: the
    # static-timer Naive must degrade more than CCP on the loss sweeps
    by = {(r["sweep"], i): r for s in ("iid", "burst", "cell")
          for i, r in enumerate(rr for rr in rows if rr["sweep"] == s)}
    for sweep in ("iid", "burst"):
        lo, hi = by[(sweep, 0)], by[(sweep, 1)]
        ccp_deg = hi["ccp"]["mean"] / lo["ccp"]["mean"]
        naive_deg = hi["naive"]["mean"] / lo["naive"]["mean"]
        assert naive_deg > ccp_deg, (sweep, ccp_deg, naive_deg)
    # the code-rate acceptance anchor: adapting the fountain overhead to
    # the measured loss process beats fixed-K CCP under burst loss
    hi = by[("burst", 1)]
    assert hi["adaptive_rate"]["mean"] < hi["ccp"]["mean"], hi
    # block baselines have no ARQ/coding slack: on the lossy burst endpoint
    # the uncoded task must be unfinishable (recorded, not averaged away)
    assert hi["uncoded_mean"]["mean"] == float("inf")

    # fig_decode: the decode-honesty figure ran, with measured LT overhead
    # and the offline anchors present per row
    ddoc = json.loads((tmp_path / "fig_decode.json").read_text())
    assert ddoc["meta"]["decoder"]["rateless_ccp"] == "in_loop"
    for r in ddoc["data"]:
        ov = r["rateless_ccp"]["overhead"]
        assert ov["frac_mean"] >= 0.0, r
        assert r["counter_gap"] > 0.0
        assert "soliton_failure" in r and "offline" in r


def test_run_smoke_fig_transport(tmp_path):
    """The transport figure runs end-to-end in the smoke lane: all three
    churn/RTT regimes, ``meta.rtt`` provenance, and the physics anchors —
    the open-loop ``best`` curve is flat (delayed observation cannot touch
    it), feedback policies pay for RTT, and at the highest-RTT burst
    point ``tfrc_ccp``'s event-rate response completes no later than
    ``ccp``'s reflexive backoff."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env["BENCH_OUT_DIR"] = str(tmp_path)
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--smoke",
         "--only", "fig_transport"],
        capture_output=True, text=True, env=env, cwd=_ROOT, timeout=1800,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert any(l.startswith("fig_transport,")
               for l in proc.stdout.splitlines())

    doc = json.loads((tmp_path / "fig_transport.json").read_text())
    assert doc["meta"]["key_schedule"] == "fold_in"
    assert set(doc["meta"]["policy"]) == {"ccp", "tfrc_ccp", "best"}
    # meta.rtt provenance: the swept means and each regime's RTT process
    rtt_meta = doc["meta"]["rtt"]
    assert rtt_meta["sweep"] == [0.0, 4.0]
    assert rtt_meta["regimes"]["iid"]["rtt_dist"] == "fixed"
    assert rtt_meta["regimes"]["burst"]["rtt_dist"] == "lognormal"
    assert rtt_meta["regimes"]["cell"]["rtt_dist"] == "cell"
    rows = doc["data"]
    assert {r["sweep"] for r in rows} == {"iid", "burst", "cell"}
    by = {(r["sweep"], r["rtt_mean"]): r for r in rows}
    for sweep in ("iid", "burst", "cell"):
        lo, hi = by[(sweep, 0.0)], by[(sweep, 4.0)]
        # open-loop pacing never reads the feedback: flat by construction
        assert hi["best"]["mean"] == lo["best"]["mean"], sweep
        # feedback pacing must pay for late observations
        assert hi["ccp"]["mean"] > lo["ccp"]["mean"], sweep
    # the TFRC acceptance anchor: at the highest-RTT burst point the
    # loss-event response is no slower than the per-loss backoff cascade
    hi = by[("burst", 4.0)]
    assert hi["tfrc_ccp"]["mean"] <= hi["ccp"]["mean"] * (1 + 1e-6), hi


def test_run_smoke_fig_fleet(tmp_path):
    """The fleet saturation sweep runs end-to-end in the smoke lane and
    its artifact carries the fleet meta (policy versions + discipline).
    The physics anchor: at the saturation knee (offered load >= 1) the
    queue-aware CCP must beat the static-timer Naive on p50 sojourn."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env["BENCH_OUT_DIR"] = str(tmp_path)
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--smoke",
         "--only", "fig_fleet"],
        capture_output=True, text=True, env=env, cwd=_ROOT, timeout=1800,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert any(l.startswith("fig_fleet,") for l in proc.stdout.splitlines())

    doc = json.loads((tmp_path / "fig_fleet.json").read_text())
    assert doc["meta"]["key_schedule"] == "fold_in"
    assert doc["meta"]["discipline"] == "fifo"
    assert set(doc["meta"]["policy"]) == {"ccp", "naive"}
    rows = doc["data"]
    assert [r["n_tasks"] for r in rows] == [1, 4]
    for r in rows:
        for pol in ("ccp", "naive"):
            assert r[pol]["p99"] >= r[pol]["p50"] > 0, (pol, r)
            assert 0 <= r[pol]["util_mean"] <= 1 + 1e-6
    # saturation bites: packing 4 tenants onto 10 helpers (12/10 offered)
    # must cost p50 sojourn vs the lone-tenant row, for every policy
    lone, knee = rows[0], rows[-1]
    assert knee["offered"] >= 1.0
    for pol in ("ccp", "naive"):
        assert knee[pol]["p50"] > lone[pol]["p50"], pol
    # the adaptivity anchor at the knee: TTI feedback sees queueing
    assert knee["ccp"]["p50"] < knee["naive"]["p50"], knee
