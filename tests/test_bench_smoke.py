"""Fast-lane smoke of the benchmark harness: ``benchmarks.run --smoke``.

Runs the churn figure end-to-end at tiny scale (2 reps, R=200, N=20,
sweep endpoints only) in a subprocess, pointing BENCH_OUT_DIR at a tmpdir
so the committed full-scale artifacts are untouched, and checks the
artifact schema: the key-schedule and policy meta markers, all three
sweeps, *every registered policy* (so a policy that breaks under
jit/vmap/shard fails this fast lane), and per-point invalid-rep counts
(dropped, never averaged).
"""

import json
import os
import pathlib
import subprocess
import sys

_ROOT = pathlib.Path(__file__).resolve().parent.parent


def test_run_smoke_fig_churn(tmp_path):
    from repro.core import policies

    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env["BENCH_OUT_DIR"] = str(tmp_path)
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--smoke", "--shard",
         "--only", "fig_churn"],
        capture_output=True, text=True, env=env, cwd=_ROOT, timeout=900,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    csv = [l for l in proc.stdout.splitlines() if l.startswith("fig_churn,")]
    assert csv, proc.stdout

    doc = json.loads((tmp_path / "fig_churn.json").read_text())
    assert doc["meta"]["key_schedule"] == "fold_in"
    # the smoke lane sweeps every registered policy, recorded in the meta
    swept = doc["meta"]["policy"]
    assert set(swept) == set(policies.names())
    rows = doc["data"]
    assert {r["sweep"] for r in rows} == {"iid", "burst", "cell"}
    for r in rows:
        for name in swept:
            assert "invalid" in r[name], (name, r)
            assert r[name]["invalid"] + 1 > 0  # present and an int
    # the endpoints tell the adaptivity story even at smoke scale: the
    # static-timer Naive must degrade more than CCP on the loss sweeps
    by = {(r["sweep"], i): r for s in ("iid", "burst", "cell")
          for i, r in enumerate(rr for rr in rows if rr["sweep"] == s)}
    for sweep in ("iid", "burst"):
        lo, hi = by[(sweep, 0)], by[(sweep, 1)]
        ccp_deg = hi["ccp"]["mean"] / lo["ccp"]["mean"]
        naive_deg = hi["naive"]["mean"] / lo["naive"]["mean"]
        assert naive_deg > ccp_deg, (sweep, ccp_deg, naive_deg)
    # the code-rate acceptance anchor: adapting the fountain overhead to
    # the measured loss process beats fixed-K CCP under burst loss
    hi = by[("burst", 1)]
    assert hi["adaptive_rate"]["mean"] < hi["ccp"]["mean"], hi
    # block baselines have no ARQ/coding slack: on the lossy burst endpoint
    # the uncoded task must be unfinishable (recorded, not averaged away)
    assert hi["uncoded_mean"]["mean"] == float("inf")
