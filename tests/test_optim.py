"""Tests for the from-scratch AdamW + schedules."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import adamw


def test_schedule_warmup_and_decay():
    cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100,
                            schedule="cosine", min_lr_frac=0.1)
    lrs = [float(adamw.schedule_lr(cfg, jnp.asarray(s))) for s in range(101)]
    assert lrs[0] == 0.0
    assert abs(lrs[10] - 1e-3) < 1e-9          # warmup peak
    assert lrs[100] < lrs[50] < lrs[10]        # monotone decay
    assert abs(lrs[100] - 1e-4) < 1e-6         # min_lr_frac floor


def test_adamw_reduces_quadratic():
    cfg = adamw.AdamWConfig(lr=0.05, weight_decay=0.0, warmup_steps=0,
                            total_steps=1000, schedule="constant")
    params = {"w": jnp.array([3.0, -2.0])}
    state = adamw.init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state, _ = adamw.apply(cfg, params, g, state)
    assert float(loss(params)) < 1e-3


def test_clip_norm_applies():
    cfg = adamw.AdamWConfig(lr=1.0, clip_norm=1.0, warmup_steps=0,
                            schedule="constant", weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    state = adamw.init(params)
    g = {"w": jnp.full(4, 100.0)}
    p2, state, metrics = adamw.apply(cfg, params, g, state)
    assert float(metrics["grad_norm"]) > 100
    # post-clip effective step is bounded by lr * 1/sqrt(v_hat)-ish ~ O(1)
    assert float(jnp.abs(p2["w"]).max()) < 2.0


def test_mixed_dtype_params_keep_dtype():
    cfg = adamw.AdamWConfig(warmup_steps=0)
    params = {"a": jnp.ones(3, jnp.bfloat16), "b": jnp.ones(3, jnp.float32)}
    state = adamw.init(params)
    g = {"a": jnp.ones(3, jnp.bfloat16), "b": jnp.ones(3, jnp.float32)}
    p2, state, _ = adamw.apply(cfg, params, g, state)
    assert p2["a"].dtype == jnp.bfloat16
    assert p2["b"].dtype == jnp.float32
    # moments always fp32
    assert state.m["a"].dtype == jnp.float32
