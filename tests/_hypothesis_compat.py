"""Minimal offline stand-in for the ``hypothesis`` subset this suite uses.

The CI container has no network and no ``hypothesis`` wheel; the property
tests only need ``given`` / ``settings`` / ``assume`` and the ``integers`` /
``floats`` / ``sampled_from`` / ``data`` strategies.  This shim replays each
property over ``max_examples`` *deterministic* seeded draws (seeded from the
test's qualified name), so failures are reproducible run-to-run.  It is NOT a
property-based testing engine: no shrinking, no coverage-guided generation —
just an exhaustive-enough deterministic sweep that keeps the invariants
exercised offline.  ``tests/conftest.py`` installs it into ``sys.modules``
only when the real ``hypothesis`` cannot be imported.
"""

from __future__ import annotations

import inspect
import types
import zlib

import numpy as np

__all__ = ["given", "settings", "assume", "strategies", "HealthCheck"]


class UnsatisfiedAssumption(Exception):
    pass


def assume(condition) -> bool:
    """Skip the current example when ``condition`` is falsy."""
    if not condition:
        raise UnsatisfiedAssumption()
    return True


class SearchStrategy:
    def do_draw(self, rng: np.random.Generator):
        raise NotImplementedError


class _Integers(SearchStrategy):
    def __init__(self, min_value, max_value):
        self.lo, self.hi = int(min_value), int(max_value)

    def do_draw(self, rng):
        return int(rng.integers(self.lo, self.hi + 1))


class _Floats(SearchStrategy):
    def __init__(self, min_value, max_value):
        self.lo, self.hi = float(min_value), float(max_value)

    def do_draw(self, rng):
        # Hit the endpoints occasionally: boundary values find most bugs.
        edge = rng.integers(0, 8)
        if edge == 0:
            return self.lo
        if edge == 1:
            return self.hi
        return float(rng.uniform(self.lo, self.hi))


class _SampledFrom(SearchStrategy):
    def __init__(self, elements):
        self.elements = list(elements)

    def do_draw(self, rng):
        return self.elements[int(rng.integers(0, len(self.elements)))]


class _Data(SearchStrategy):
    """Marker; resolved to a DataObject bound to the example's rng."""


class DataObject:
    def __init__(self, rng: np.random.Generator):
        self._rng = rng

    def draw(self, strategy: SearchStrategy, label=None):
        return strategy.do_draw(self._rng)


def integers(min_value=0, max_value=2**31 - 1) -> SearchStrategy:
    return _Integers(min_value, max_value)


def floats(min_value=0.0, max_value=1.0, **_kw) -> SearchStrategy:
    return _Floats(min_value, max_value)


def sampled_from(elements) -> SearchStrategy:
    return _SampledFrom(elements)


def data() -> SearchStrategy:
    return _Data()


strategies = types.SimpleNamespace(
    integers=integers, floats=floats, sampled_from=sampled_from, data=data
)
strategies.__name__ = "hypothesis.strategies"


class HealthCheck:
    all = classmethod(lambda cls: [])
    too_slow = "too_slow"
    data_too_large = "data_too_large"


_DEFAULT_MAX_EXAMPLES = 20


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, **_ignored):
    """Records ``max_examples``; deadline/suppress_health_check are no-ops."""

    def deco(fn):
        fn._compat_max_examples = max_examples
        return fn

    return deco


def given(*arg_strategies, **kw_strategies):
    if arg_strategies:
        raise TypeError(
            "the hypothesis compat shim supports keyword strategies only"
        )

    def deco(fn):
        sig = inspect.signature(fn)

        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_compat_max_examples", _DEFAULT_MAX_EXAMPLES)
            seed = zlib.crc32(fn.__qualname__.encode())
            ran = 0
            # Extra attempts absorb assume() rejections.
            for example in range(n * 10):
                if ran >= n:
                    break
                rng = np.random.default_rng([seed, example])
                drawn = {
                    name: DataObject(rng) if isinstance(s, _Data)
                    else s.do_draw(rng)
                    for name, s in kw_strategies.items()
                }
                try:
                    fn(*args, **kwargs, **drawn)
                except UnsatisfiedAssumption:
                    continue
                ran += 1
            if ran == 0:
                raise RuntimeError(
                    f"{fn.__qualname__}: assume() rejected every example"
                )

        # Pytest must not treat the strategy-supplied params as fixtures:
        # expose a signature with only the remaining (fixture) parameters.
        # Deliberately no functools.wraps: __wrapped__ would leak the
        # original signature through pytest's unwrapping.
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        wrapper.__signature__ = sig.replace(
            parameters=[
                p for name, p in sig.parameters.items()
                if name not in kw_strategies
            ]
        )
        wrapper._compat_max_examples = getattr(
            fn, "_compat_max_examples", _DEFAULT_MAX_EXAMPLES
        )
        return wrapper

    return deco
