"""Tests for the CCP runtime scheduler (telemetry -> allocation)."""

import numpy as np

from repro.core.scheduler import CCPScheduler


def test_allocation_tracks_speed():
    """Workers 2x faster must converge to ~2x the microbatches (eq. 23)."""
    sched = CCPScheduler(n_workers=4)
    speeds = np.array([1.0, 1.0, 2.0, 2.0])  # units/sec
    for _ in range(30):
        alloc = sched.allocation(24)
        durations = alloc / speeds + 1e-4
        sched.observe_step(durations)
    alloc = sched.allocation(24)
    assert alloc.sum() == 24
    fast = alloc[2:].mean()
    slow = alloc[:2].mean()
    assert 1.6 < fast / slow < 2.5, alloc


def test_adapts_to_speed_change():
    """Time-varying resources: a worker that slows down mid-run loses share."""
    sched = CCPScheduler(n_workers=2, alpha=0.5)
    for step in range(60):
        alloc = sched.allocation(20)
        speed0 = 2.0 if step < 30 else 0.25
        durations = [alloc[0] / speed0, alloc[1] / 1.0]
        sched.observe_step(durations)
    alloc = sched.allocation(20)
    assert alloc[0] < alloc[1], alloc


def test_timeout_backoff_and_death():
    sched = CCPScheduler(n_workers=3, drop_after=2)
    for _ in range(5):
        sched.allocation(9)
        sched.observe_step([1.0, 1.0, np.inf])  # worker 2 unresponsive
    assert sched.dead_mask()[2]
    assert not sched.dead_mask()[0]
    alloc = sched.allocation(9)
    assert alloc[2] == 0, "dead worker must get no work"
    assert alloc.sum() == 9


def test_recovery_restores_share():
    sched = CCPScheduler(n_workers=2, drop_after=4)
    speeds = np.array([1.0, 1.0])
    for _ in range(3):
        a = sched.allocation(8)
        sched.observe_step([a[0] / speeds[0], np.inf])
    degraded = sched.allocation(8)
    for _ in range(20):
        a = sched.allocation(8)
        sched.observe_step(a / speeds)  # worker 1 responsive again, same speed
    recovered = sched.allocation(8)
    assert recovered[1] >= degraded[1]
    assert recovered[1] >= 3  # near-equal share restored


def test_deadline_scales_with_estimate():
    sched = CCPScheduler(n_workers=2)
    sched.allocation(4)
    sched.observe_step([1.0, 4.0])
    d = sched.timeout_deadline()
    assert d[1] > d[0]
