"""Integration: coded-DP training step (shard_map, R-of-(R+K) aggregation).

Runs on 8 host devices (spawned via a subprocess so the 1-device test
session is unaffected) — asserts that (i) the coded step with no stragglers
matches the uncoded gradient step, and (ii) dropping a worker's systematic
contribution with decode weights still yields the same update.
"""

import json
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + \
        " --xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.configs import get_config
    from repro.core import gradient_coding as gc
    from repro.launch.mesh import make_host_mesh
    from repro.models import build_model
    from repro.optim import adamw
    from repro.runtime.train_loop import make_coded_train_step, make_train_step

    cfg = get_config("mistral-nemo-12b", smoke=True)
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=0, schedule="constant",
                                weight_decay=0.0)
    mesh = make_host_mesh(data=8, model=1)
    R = 8
    step, code, (pats, ws) = make_coded_train_step(
        model, opt_cfg, mesh, n_parity=4, seed=0)

    tok = jax.random.randint(jax.random.PRNGKey(1), (R, 2, 16), 0, cfg.vocab)
    batch = {"tokens": tok, "labels": tok}

    # reference: plain (uncoded) data-parallel gradients
    def ref_grads(params):
        g = None
        for r in range(R):
            mb = {k: v[r] for k, v in batch.items()}
            gi = jax.grad(model.loss_fn)(params, mb)
            g = gi if g is None else jax.tree.map(lambda a, b: a + b, g, gi)
        return jax.tree.map(lambda a: a / R, g)

    opt_state = adamw.init(params)
    gref = ref_grads(params)
    pref, _, _ = adamw.apply(opt_cfg, params, gref, opt_state)

    out = {}
    # (i) no stragglers: systematic weights
    w0 = jnp.asarray(ws[0])
    p1, _, m1 = step(params, adamw.init(params), batch, w0)
    err0 = max(float(jnp.abs(a - b).max())
               for a, b in zip(jax.tree.leaves(pref), jax.tree.leaves(p1)))
    out["err_no_straggler"] = err0

    # (ii) drop one worker, use a decode-weight pattern that excludes it
    lost = None
    for pat, w in zip(pats[1:], ws[1:]):
        missing = np.flatnonzero(~pat[:R])
        if len(missing) == 1:
            lost = int(missing[0]); wv = w; break
    if lost is None:
        surv = np.setdiff1d(np.arange(R + code.K), [0])
        wd = gc.decode_weights(code, surv)
        wv = np.zeros(R + code.K, np.float32); wv[surv] = wd; lost = 0
    p2, _, m2 = step(params, adamw.init(params), batch, jnp.asarray(wv))
    err1 = max(float(jnp.abs(a - b).max())
               for a, b in zip(jax.tree.leaves(pref), jax.tree.leaves(p2)))
    out["err_with_straggler"] = err1
    out["loss"] = float(m1["loss"])
    print("RESULT:" + json.dumps(out))
    """
)


@pytest.mark.slow
def test_coded_train_step_matches_uncoded():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        env=env, cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=900,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT:")][0]
    out = json.loads(line[len("RESULT:"):])
    assert out["err_no_straggler"] < 5e-5, out
    assert out["err_with_straggler"] < 5e-5, out
    assert out["loss"] > 0
