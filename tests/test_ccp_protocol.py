"""Tests for the CCP estimator (Algorithm 1 state machine)."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import ccp


def _cfg(R=1000, alpha=0.25):
    return ccp.CCPConfig(Bx=8.0 * R, Br=8.0, Back=1.0, alpha=alpha)


def test_fraction_constants():
    c = _cfg(R=1000)
    np.testing.assert_allclose(c.data_scale, (8000 + 8) / (8000 + 1))
    np.testing.assert_allclose(c.back_frac, 8 / 8008)
    np.testing.assert_allclose(c.fwd_frac, 8000 / 8001)


def test_first_packet_initialization():
    """Alg.1 lines 6-7: first packet sets Tu to the forward-trip estimate and
    seeds the EWMA with the first RTT sample."""
    c = _cfg()
    s = ccp.init_state(1)
    rtt_ack = jnp.array([0.010])
    tx, tr = jnp.array([0.0]), jnp.array([1.0])
    s1, tti = ccp.on_computed(s, c, tx, tr, jnp.zeros(1), rtt_ack, jnp.array([True]))
    np.testing.assert_allclose(float(s1.rtt_data[0]), c.data_scale * 0.010, rtol=1e-6)
    np.testing.assert_allclose(float(s1.Tu[0]), c.fwd_frac * 0.010, rtol=1e-6)
    assert int(s1.m[0]) == 1
    # E[beta] ~ Tr - back_trip - Tu ~ 1.0 - small
    assert 0.97 < float(s1.e_beta[0]) < 1.0
    # eq. (8): TTI <= Tr - Tx
    assert float(tti[0]) <= 1.0 + 1e-6


def test_estimator_converges_to_true_mean():
    """Feed a synthetic ideal stream: beta=0.5 exactly, tiny RTT. E[beta] -> 0.5."""
    c = _cfg()
    s = ccp.init_state(1)
    rtt = 0.002
    beta = 0.5
    tx_prev = 0.0
    tr_prev = jnp.zeros(1)
    for i in range(200):
        tx = jnp.array([i * beta])  # ideal pacing
        tr = jnp.array([i * beta + beta + rtt])
        s, tti = ccp.on_computed(s, c, tx, tr, tr_prev, jnp.array([rtt]), jnp.array([True]))
        tr_prev = tr
    assert abs(float(s.e_beta[0]) - beta) < 0.02
    assert abs(float(tti[0]) - beta) < 0.02


def test_underutilization_accumulates_when_idle():
    """If packets are sent far apart (XTT << RTT^data), Tu must grow."""
    c = _cfg()
    s = ccp.init_state(1)
    rtt = 0.01
    gap = 2.0  # collector sends every 2s; compute takes 0.5s -> idle 1.5s/packet
    tr_prev = jnp.zeros(1)
    tus = []
    for i in range(10):
        tx = jnp.array([i * gap])
        tr = jnp.array([i * gap + 0.5 + rtt])
        s, _ = ccp.on_computed(s, c, tx, tr, tr_prev, jnp.array([rtt]), jnp.array([True]))
        tr_prev = tr
        tus.append(float(s.Tu[0]))
    assert tus[-1] > tus[1], "Tu should accumulate under-utilization"
    # E[beta] stays near 0.5 despite the idle gaps (that's the whole point
    # of the Tu correction in eq. (5))
    assert abs(float(s.e_beta[0]) - 0.5) < 0.05


def test_timeout_backoff_doubles_and_resets():
    s = ccp.init_state(2)
    s = s.replace(e_beta=jnp.array([1.0, 1.0]))
    s = ccp.on_timeout(s, jnp.array([True, False]))
    s = ccp.on_timeout(s, jnp.array([True, False]))
    t = ccp.tti(s, jnp.array([10.0, 10.0]))
    np.testing.assert_allclose(np.asarray(t), [4.0, 1.0])
    # a successful receipt resets the backoff
    c = _cfg()
    s2, _ = ccp.on_computed(
        s, c, jnp.zeros(2), jnp.ones(2), jnp.zeros(2),
        jnp.array([0.01, 0.01]), jnp.array([True, True]),
    )
    np.testing.assert_allclose(np.asarray(s2.tti_backoff), [1.0, 1.0])


def test_inactive_helpers_unchanged():
    c = _cfg()
    s = ccp.init_state(3)
    active = jnp.array([True, False, True])
    s1, _ = ccp.on_computed(
        s, c, jnp.zeros(3), jnp.ones(3), jnp.zeros(3),
        jnp.full(3, 0.01), active,
    )
    assert int(s1.m[1]) == 0
    assert float(s1.rtt_data[1]) == 0.0


@settings(max_examples=30, deadline=None)
@given(
    beta=st.floats(0.05, 5.0),
    rtt=st.floats(1e-4, 0.05),
    n_pkts=st.integers(5, 60),
)
def test_property_tti_never_exceeds_round_trip(beta, rtt, n_pkts):
    """Invariant (8): TTI_{n,i} <= Tr_{n,i} - Tx_{n,i} always."""
    c = _cfg()
    s = ccp.init_state(1)
    tr_prev = jnp.zeros(1)
    for i in range(n_pkts):
        tx = jnp.array([i * beta])
        tr = jnp.array([i * beta + beta + rtt])
        s, tti = ccp.on_computed(s, c, tx, tr, tr_prev, jnp.array([rtt]), jnp.array([True]))
        assert float(tti[0]) <= float(tr[0] - tx[0]) + 1e-6
        assert float(s.e_beta[0]) > 0
        tr_prev = tr
