"""End-to-end behaviour tests for the paper's system.

The full claim chain on one box:
  1. CCP completes y=Ax faster than the uncoded/HCMM baselines and within a
     small factor of the optimum (the paper's headline).
  2. The training framework built on the same machinery learns: loss on the
     deterministic synthetic stream decreases over a few dozen steps.
  3. Checkpoint/restart mid-run is bit-exact for the data stream and
     continues the loss curve.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.ccp_paper import FIG3
from repro.core import baselines, engine, simulator, theory

run_ccp = lambda key, cfg, R: engine.Engine().run_one(key, cfg, "ccp", R)
from repro.data import SyntheticLM
from repro.models import build_model
from repro.optim import adamw
from repro.runtime.train_loop import make_train_step


def test_paper_headline_end_to_end():
    cfg, R = FIG3[1], 1500
    reps = 5
    t = lambda fn: float(np.mean(
        [fn(jax.random.PRNGKey(i), cfg, R)["T"] for i in range(reps)]))
    t_ccp = t(run_ccp)
    t_unc = t(lambda k, c, r: baselines.run_uncoded(k, c, r, "mean"))
    t_hcmm = t(baselines.run_hcmm)
    o = run_ccp(jax.random.PRNGKey(0), cfg, R)
    t_opt = theory.t_opt_model1(R, cfg.K(R), o["a"], o["mu"])
    assert t_ccp < t_unc and t_ccp < t_hcmm
    assert t_ccp < t_opt * 1.25  # close to optimum analysis


@pytest.fixture(scope="module")
def trained():
    cfg = get_config("mistral-nemo-12b", smoke=True)
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    opt_cfg = adamw.AdamWConfig(lr=5e-3, warmup_steps=5, total_steps=60,
                                weight_decay=0.01)
    data = SyntheticLM(cfg.vocab, seq_len=32, global_batch=8, n_micro=2, seed=0)
    step = jax.jit(make_train_step(model, opt_cfg, 2, pre_shaped=True))
    opt_state = adamw.init(params)
    losses = []
    for s in range(40):
        batch = {k: jnp.asarray(v) for k, v in data.batch(s).items()}
        params, opt_state, m = step(params, opt_state, batch)
        losses.append(float(m["loss"]))
    return cfg, model, params, opt_state, losses, data


def test_training_learns(trained):
    _, _, _, _, losses, _ = trained
    assert all(np.isfinite(losses))
    first = np.mean(losses[:5])
    last = np.mean(losses[-5:])
    assert last < first - 0.5, f"loss did not decrease: {first} -> {last}"


def test_checkpoint_restart_continues_loss_curve(trained, tmp_path):
    from repro import checkpoint as ck

    cfg, model, params, opt_state, losses, data = trained
    ck.save(tmp_path, 40, {"params": params, "opt": opt_state})
    tgt = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                       {"params": params, "opt": opt_state})
    restored, _ = ck.restore(tmp_path, 40, tgt)
    opt_cfg = adamw.AdamWConfig(lr=5e-3, warmup_steps=5, total_steps=60,
                                weight_decay=0.01)
    step = jax.jit(make_train_step(model, opt_cfg, 2, pre_shaped=True))
    p2, o2 = restored["params"], restored["opt"]
    cont = []
    for s in range(40, 45):
        batch = {k: jnp.asarray(v) for k, v in data.batch(s).items()}
        p2, o2, m = step(p2, o2, batch)
        cont.append(float(m["loss"]))
    assert all(np.isfinite(cont))
    assert np.mean(cont) < np.mean(losses[:5]), "restart lost progress"
    # bit-exact state roundtrip: one more step from the live state matches
    batch = {k: jnp.asarray(v) for k, v in data.batch(40).items()}
    p_live, _, m_live = step(params, opt_state, batch)
    np.testing.assert_allclose(cont[0], float(m_live["loss"]), rtol=1e-5)
