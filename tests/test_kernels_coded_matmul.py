"""Shape/dtype sweeps: Pallas coded_matmul + lt_encode vs. pure-jnp oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import fountain
from repro.kernels.coded_matmul import coded_matmul, coded_matmul_code, coded_matmul_ref
from repro.kernels.coded_matmul.ref import lt_encode_ref
from repro.kernels.lt_encode import lt_encode

TOL = {jnp.float32: 1e-5, jnp.bfloat16: 2e-2}


def _mk(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "R,K,bm,kdim,ndim,bk,bn",
    [
        (4, 2, 8, 16, 16, 8, 8),
        (6, 3, 16, 64, 32, 16, 16),
        (8, 4, 8, 128, 128, 128, 128),   # MXU-aligned tiles
        (3, 2, 32, 48, 24, 16, 8),       # non-square, odd tile counts
        (10, 5, 8, 32, 8, 32, 8),        # single k tile
    ],
)
def test_coded_matmul_sweep(R, K, bm, kdim, ndim, bk, bn, dtype):
    code = fountain.make_lt_code(R=R, K=K, seed=R * 31 + K)
    a = _mk(jax.random.PRNGKey(0), (R * bm, kdim), dtype)
    x = _mk(jax.random.PRNGKey(1), (kdim, ndim), dtype)
    idx, mask = jnp.asarray(code.idx), jnp.asarray(code.mask)
    ref = coded_matmul_ref(a, x, idx, mask, bm)
    out = coded_matmul(
        a, x, idx, mask, bm=bm, bk=bk, bn=bn, use_pallas=True, interpret=True
    )
    assert out.shape == ((R + K) * bm, ndim)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        rtol=TOL[dtype], atol=TOL[dtype] * 8,
    )


def test_coded_matmul_padding_path():
    """Non-divisible k/n dims go through the padded path."""
    code = fountain.make_lt_code(R=4, K=2, seed=7)
    a = _mk(jax.random.PRNGKey(2), (4 * 8, 20), jnp.float32)
    x = _mk(jax.random.PRNGKey(3), (20, 13), jnp.float32)
    idx, mask = jnp.asarray(code.idx), jnp.asarray(code.mask)
    ref = coded_matmul_ref(a, x, idx, mask, 8)
    out = coded_matmul(
        a, x, idx, mask, bm=8, bk=16, bn=8, use_pallas=True, interpret=True
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


def test_coded_matmul_code_convenience():
    code = fountain.make_lt_code(R=5, K=2, seed=3)
    a = _mk(jax.random.PRNGKey(4), (5 * 16, 32), jnp.float32)
    x = _mk(jax.random.PRNGKey(5), (32, 16), jnp.float32)
    out = coded_matmul_code(a, x, code, use_pallas=True, interpret=True, bk=16, bn=16)
    ref = coded_matmul_ref(a, x, jnp.asarray(code.idx),
                           jnp.asarray(code.weights), 16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


def test_coded_matmul_systematic_prefix_is_plain_matmul():
    """The systematic prefix of the output must equal A @ x exactly."""
    code = fountain.make_lt_code(R=4, K=3, seed=11)
    a = _mk(jax.random.PRNGKey(6), (4 * 8, 32), jnp.float32)
    x = _mk(jax.random.PRNGKey(7), (32, 16), jnp.float32)
    out = coded_matmul(
        a, x, jnp.asarray(code.idx), jnp.asarray(code.mask),
        bm=8, bk=16, bn=16, use_pallas=True, interpret=True,
    )
    np.testing.assert_allclose(
        np.asarray(out[: 4 * 8]), np.asarray(a @ x), rtol=1e-4, atol=1e-4
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "R,K,bm,ncols,bc",
    [(4, 2, 8, 16, 8), (8, 4, 16, 128, 128), (5, 3, 8, 24, 8), (2, 1, 128, 256, 256)],
)
def test_lt_encode_sweep(R, K, bm, ncols, bc, dtype):
    code = fountain.make_lt_code(R=R, K=K, seed=R * 17 + K)
    a = _mk(jax.random.PRNGKey(8), (R * bm, ncols), dtype)
    idx, mask = jnp.asarray(code.idx), jnp.asarray(code.mask)
    ref = lt_encode_ref(a, idx, mask, bm)
    out = lt_encode(a, idx, mask, bm=bm, bc=bc, use_pallas=True, interpret=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        rtol=TOL[dtype], atol=TOL[dtype] * 4,
    )


@settings(max_examples=10, deadline=None)
@given(
    R=st.integers(2, 8),
    K=st.integers(1, 4),
    bm=st.sampled_from([8, 16]),
    kt=st.integers(1, 3),
    nt=st.integers(1, 3),
    seed=st.integers(0, 100),
)
def test_property_kernel_matches_oracle(R, K, bm, kt, nt, seed):
    """Encode-matmul fusion == encode_ref ∘ matmul for random codes/shapes."""
    code = fountain.make_lt_code(R=R, K=K, seed=seed)
    kdim, ndim = 8 * kt, 8 * nt
    a = _mk(jax.random.PRNGKey(seed), (R * bm, kdim), jnp.float32)
    x = _mk(jax.random.PRNGKey(seed + 1), (kdim, ndim), jnp.float32)
    idx, w = jnp.asarray(code.idx), jnp.asarray(code.weights)
    out = coded_matmul(
        a, x, idx, w, bm=bm, bk=8, bn=8, use_pallas=True, interpret=True
    )
    enc = fountain.encode(a.reshape(R, bm, kdim), code).reshape(-1, kdim)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(enc @ x), rtol=2e-4, atol=2e-4
    )
