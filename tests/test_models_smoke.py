"""Per-architecture smoke tests: reduced config, one forward + one train
step on CPU, asserting output shapes and no NaNs; plus prefill/decode-step
consistency against the full forward pass."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import build_model


def _batch_for(cfg, key, B=2, T=16):
    kt, ke = jax.random.split(key)
    tokens = jax.random.randint(kt, (B, T), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.frontend == "audio_stub":
        batch["embeds"] = jax.random.normal(ke, (B, cfg.enc_frames, cfg.d_model)) * 0.02
    elif cfg.frontend == "vision_stub":
        batch["embeds"] = jax.random.normal(ke, (B, cfg.n_patches, cfg.d_model)) * 0.02
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finiteness(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params, axes = model.init(jax.random.PRNGKey(0))
    # axes tree mirrors params tree
    assert set(jax.tree.leaves(jax.tree.map(lambda _: 1, params))) == {1}
    batch = _batch_for(cfg, jax.random.PRNGKey(1))
    if cfg.enc_dec:
        logits = model.forward(params, batch["tokens"], batch["embeds"])
        exp_t = batch["tokens"].shape[1]
    else:
        logits = model.forward(params, batch["tokens"], batch.get("embeds"))
        exp_t = batch["tokens"].shape[1] + (
            batch["embeds"].shape[1] if batch.get("embeds") is not None else 0
        )
    assert logits.shape == (2, exp_t, cfg.vocab), logits.shape
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step_no_nans(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    batch = _batch_for(cfg, jax.random.PRNGKey(1))

    loss, grads = jax.value_and_grad(model.loss_fn)(params, batch)
    assert bool(jnp.isfinite(loss)), f"{arch}: loss={loss}"
    # CE at init should be near log(vocab)
    assert float(loss) < np.log(cfg.vocab) + 2.0
    gflat = jax.tree.leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in gflat), f"{arch}: NaN grads"
    # gradient must actually flow to the embedding
    assert float(jnp.abs(grads["embed"]).max()) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_then_decode_matches_forward(arch):
    """KV-cache/recurrent-state decode must agree with the full pass."""
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    B, T = 2, 12
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, T), 0, cfg.vocab)
    kw = {}
    if cfg.frontend == "audio_stub":
        kw["embeds"] = jax.random.normal(
            jax.random.PRNGKey(3), (B, cfg.enc_frames, cfg.d_model)) * 0.02
    elif cfg.frontend == "vision_stub":
        kw["embeds"] = jax.random.normal(
            jax.random.PRNGKey(3), (B, cfg.n_patches, cfg.d_model)) * 0.02

    if cfg.enc_dec:
        full = model.forward(params, tokens, kw["embeds"])
    else:
        full = model.forward(params, tokens, kw.get("embeds"))

    cache = model.init_cache(B, max_len=64)
    if cfg.enc_dec:
        last, cache = model.prefill(params, tokens[:, :-1], cache, embeds=kw["embeds"])
    elif kw.get("embeds") is not None:
        # vlm: prefix embeds are part of the prefill
        last, cache = model.prefill(params, tokens[:, :-1], cache, embeds=kw["embeds"])
    else:
        last, cache = model.prefill(params, tokens[:, :-1], cache)
    step, cache = model.decode_step(params, tokens[:, -1:], cache)
    np.testing.assert_allclose(
        np.asarray(step), np.asarray(full[:, -1]), rtol=5e-3, atol=5e-3,
    )


@pytest.mark.parametrize("arch", ["xlstm-350m", "recurrentgemma-2b"])
def test_recurrent_state_is_O1_in_seq(arch):
    """The long_500k applicability rule: state size must not grow with the
    cache length for SSM/hybrid archs (modulo the bounded local window)."""
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)

    def state_bytes(max_len):
        cache = model.init_cache(1, max_len=max_len)
        return sum(
            x.size * x.dtype.itemsize
            for x in jax.tree.leaves(cache)
        )

    b1, b2 = state_bytes(64), state_bytes(128)
    if arch == "xlstm-350m":
        assert b1 == b2, "xLSTM state must be O(1) in sequence length"
    else:
        # hybrid: only the local-attn window cache grows (bounded by window)
        assert b2 <= 2.5 * b1


def test_param_counts_match_table():
    """n_params() sanity against the published sizes (within 25%)."""
    expected = {
        "gemma2-27b": 27e9,
        "mistral-nemo-12b": 12e9,
        "phi4-mini-3.8b": 3.8e9,
        "granite-20b": 20e9,
        "llava-next-34b": 34e9,
        # the *assigned* config (48L x 64e x d_ff 1408) computes to ~29B;
        # the production Moonlight-16B-A3B has 27 layers.  We implement the
        # assigned numbers exactly, so the expectation follows the config.
        "moonshot-v1-16b-a3b": 28.9e9,
        "qwen3-moe-235b-a22b": 235e9,
        "whisper-large-v3": 1.5e9,
        "recurrentgemma-2b": 2.7e9,
        "xlstm-350m": 0.35e9,
    }
    for arch, target in expected.items():
        n = get_config(arch).n_params()
        assert 0.6 * target < n < 1.6 * target, (arch, n, target)


def test_moe_active_params():
    cfg = get_config("qwen3-moe-235b-a22b")
    act = cfg.n_active_params()
    assert 15e9 < act < 30e9, act  # ~22B active
