"""Tests for the deterministic synthetic data pipeline."""

import numpy as np

from repro.data import Prefetcher, SyntheticLM


def test_batches_deterministic_in_step():
    d1 = SyntheticLM(vocab=100, seq_len=8, global_batch=4, n_micro=2, seed=7)
    d2 = SyntheticLM(vocab=100, seq_len=8, global_batch=4, n_micro=2, seed=7)
    b1, b2 = d1.batch(13), d2.batch(13)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # different steps differ
    b3 = d1.batch(14)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_shapes_and_label_shift():
    d = SyntheticLM(vocab=50, seq_len=8, global_batch=6, n_micro=3, seed=0)
    b = d.batch(0)
    assert b["tokens"].shape == (3, 2, 8)
    assert b["labels"].shape == (3, 2, 8)
    # labels are next-token targets of the same underlying stream
    np.testing.assert_array_equal(b["tokens"][..., 1:], b["labels"][..., :-1])


def test_learnable_signal_present():
    """The copy-period structure makes some labels predictable."""
    d = SyntheticLM(vocab=1000, seq_len=64, global_batch=8, seed=1, copy_period=4)
    b = d.batch(0)
    t, l = b["tokens"], b["labels"]
    copies = (t == l).mean()
    assert copies > 0.15  # ~1/copy_period of positions copy


def test_prefetcher_order_and_stop():
    d = SyntheticLM(vocab=100, seq_len=8, global_batch=4, seed=3)
    pf = Prefetcher(d, start_step=5, depth=2)
    s0, b0 = pf.next()
    s1, b1 = pf.next()
    assert (s0, s1) == (5, 6)
    np.testing.assert_array_equal(b0["tokens"], d.batch(5)["tokens"])
    pf.stop()


def test_restart_reproduces_stream():
    """Resuming at step k yields the same batch a fresh run would see."""
    d = SyntheticLM(vocab=100, seq_len=8, global_batch=4, seed=9)
    fresh = d.batch(42)
    resumed = SyntheticLM(vocab=100, seq_len=8, global_batch=4, seed=9).batch(42)
    np.testing.assert_array_equal(fresh["tokens"], resumed["tokens"])
