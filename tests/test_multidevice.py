"""Multi-device distribution tests on 8 host devices (subprocess-isolated so
the main test session keeps its single-device view).

Covers: GSPMD-sharded train step vs single-device reference, the shard_map
coded matmul mesh path, and the sharded cross-entropy collective helper.
"""

import json
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + \
        " --xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    from repro.configs import get_config
    from repro.core import coded_matmul as cm
    from repro.launch.mesh import make_host_mesh
    from repro.models import build_model, cross_entropy
    from repro.optim import adamw
    from repro.parallel import sharding as shd
    from repro.parallel.collectives import sharded_cross_entropy
    from repro.runtime.train_loop import make_train_step

    out = {}

    # ---- 1. sharded train step == single-device step ----------------------
    cfg = get_config("gemma2-27b", smoke=True)
    model = build_model(cfg)
    params, axes = model.init(jax.random.PRNGKey(0))
    opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=0, schedule="constant",
                                weight_decay=0.0)
    step = make_train_step(model, opt_cfg, 2, pre_shaped=True)
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 4, 16), 0, cfg.vocab)
    batch = {"tokens": tok, "labels": tok}

    p1, o1, m1 = jax.jit(step)(params, adamw.init(params), batch)  # 1 device

    mesh = make_host_mesh(data=4, model=2)
    rules = shd.make_rules(cfg, mesh)
    p_sh = shd.param_shardings(mesh, axes, rules)
    params_d = jax.device_put(params, p_sh)
    with mesh:
        p2, o2, m2 = jax.jit(step, in_shardings=(p_sh, None, None),
                             out_shardings=(p_sh, None, None))(
            params_d, adamw.init(params_d), batch)
    err = max(float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max())
              for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
    out["train_step_max_err"] = err
    out["loss_diff"] = abs(float(m1["loss"]) - float(m2["loss"]))

    # ---- 2. coded matmul over a real mesh ---------------------------------
    plan = cm.plan_coded_matmul(rows=256, n_shards=8, overhead=0.5, bm=16)
    a = jax.random.normal(jax.random.PRNGKey(2), (256, 64))
    x = jax.random.normal(jax.random.PRNGKey(3), (64, 32))
    mesh8 = make_host_mesh(data=1, model=8)
    o_mesh = cm.run(plan, a, x, mesh=mesh8, axis="model")
    o_ref = cm.run(plan, a, x)
    out["coded_matmul_mesh_err"] = float(jnp.abs(o_mesh - o_ref).max())
    y = cm.recover(plan, o_mesh, survivors=np.array([0, 2, 3, 4, 5, 6, 7]))
    out["coded_matmul_recover_err"] = float(jnp.abs(y - a @ x).max())

    # ---- 3. sharded cross-entropy == dense cross-entropy ------------------
    V, B, T = 64, 2, 8
    logits = jax.random.normal(jax.random.PRNGKey(4), (B, T, V))
    labels = jax.random.randint(jax.random.PRNGKey(5), (B, T), 0, V)
    dense = float(cross_entropy(logits, labels))

    mesh_v = make_host_mesh(data=1, model=8)

    def local_ce(lg, lb):
        idx = jax.lax.axis_index("model")
        vstart = idx * (V // 8)
        return sharded_cross_entropy(lg, lb, vstart, "model")

    ce = shard_map(local_ce, mesh=mesh_v,
                   in_specs=(P(None, None, "model"), P()),
                   out_specs=P(), check_rep=False)(logits, labels)
    out["sharded_ce_err"] = abs(float(ce) - dense)
    print("RESULT:" + json.dumps(out))
    """
)


@pytest.mark.slow
def test_multidevice_distribution():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        env=env, cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=1500,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT:")][0]
    out = json.loads(line[len("RESULT:"):])
    assert out["train_step_max_err"] < 2e-4, out
    assert out["loss_diff"] < 1e-4, out
    assert out["coded_matmul_mesh_err"] < 1e-4, out
    assert out["coded_matmul_recover_err"] < 5e-3, out
    assert out["sharded_ce_err"] < 1e-5, out
