"""Tests for coded gradient aggregation (R-of-(R+K) DP)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import gradient_coding as gc


def test_parity_assignments_match_code():
    code = gc.make_gradient_code(8, 4, seed=0)
    assigns = gc.parity_assignments(code)
    assert len(assigns) == 4
    for k, nbrs in enumerate(assigns):
        row = code.R + k
        assert set(nbrs) == set(code.idx[row][code.mask[row]].tolist())
        assert len(nbrs) <= 4  # d_max cap = compute redundancy bound


def test_decode_weights_no_stragglers_is_systematic():
    code = gc.make_gradient_code(8, 4, seed=1)
    w = gc.decode_weights(code, np.arange(8))
    np.testing.assert_allclose(w, np.ones(8), atol=1e-6)


def test_decode_weights_recover_sum_with_losses():
    code = gc.make_gradient_code(8, 4, seed=2)
    rng = np.random.default_rng(0)
    grads = rng.normal(size=(8, 5))
    G = code.dense_generator()
    coded = G @ grads  # (12, 5): systematic + parities
    for lost in ([0], [3], [7, 2]):
        surv = np.setdiff1d(np.arange(12), lost)
        try:
            w = gc.decode_weights(code, surv)
        except ValueError:
            continue  # undecodable pattern: legal, fountain contract
        rec = w @ coded[surv]
        np.testing.assert_allclose(rec, grads.sum(0), atol=1e-5)


def test_coded_grad_sum_jnp():
    code = gc.make_gradient_code(4, 2, seed=3)
    grads = jnp.asarray(np.random.default_rng(1).normal(size=(4, 3)))
    G = code.dense_generator()
    parities = jnp.asarray(G[4:] @ np.asarray(grads))
    # lose worker 1's systematic result
    surv = [0, 2, 3, 4, 5]
    w = gc.decode_weights(code, surv)
    wfull = np.zeros(6, np.float32)
    wfull[surv] = w
    rec = gc.coded_grad_sum(grads, parities, jnp.asarray(wfull))
    np.testing.assert_allclose(np.asarray(rec), np.asarray(grads.sum(0)), atol=1e-5)


def test_weight_table_patterns_valid():
    code = gc.make_gradient_code(8, 4, seed=4)
    pats, ws = gc.weight_table(code, max_stragglers=2, seed=0, n_patterns=16)
    G = code.dense_generator()
    for pat, w in zip(pats, ws):
        np.testing.assert_allclose(w @ G, np.ones(8), atol=1e-5)
        assert np.all(w[~pat] == 0)


def test_expected_redundancy_bounded():
    code = gc.make_gradient_code(16, 4, seed=5)
    r = gc.expected_redundancy(code)
    assert 0 < r <= 4 * 4 / 16 + 1e-9  # K * d_max / R


@settings(max_examples=20, deadline=None)
@given(R=st.integers(4, 16), K=st.integers(2, 6), seed=st.integers(0, 200))
def test_property_single_loss_always_recoverable(R, K, seed):
    """Coverage guarantees any single systematic loss decodes — feasible
    whenever the parity slot budget K*d_max can cover all R sources."""
    from hypothesis import assume

    assume(K * 4 >= R)  # d_max=4 in make_gradient_code
    code = gc.make_gradient_code(R, K, seed=seed)
    lost = seed % R
    surv = np.setdiff1d(np.arange(R + K), [lost])
    w = gc.decode_weights(code, surv)  # must not raise
    G = code.dense_generator()
    np.testing.assert_allclose(w @ G[surv], np.ones(R), atol=1e-5)
