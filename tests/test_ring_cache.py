"""Ring-buffer local-attention cache must decode identically to the
full-context cache (the long_500k §Perf optimization is a pure layout
change)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model


def test_ring_cache_matches_full_cache():
    cfg = get_config("recurrentgemma-2b", smoke=True)  # window=8 local attn
    full = build_model(cfg)
    ring = build_model(cfg, ring_local=True)
    params, _ = full.init(jax.random.PRNGKey(0))

    B, T_prompt, n_new = 2, 4, 14  # decode well past the window
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, T_prompt), 0, cfg.vocab)
    max_len = T_prompt + n_new + 2

    def run(model):
        cache = model.init_cache(B, max_len)
        logits, cache = model.prefill(params, toks, cache)
        outs = [logits]
        cur = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        for _ in range(n_new):
            logits, cache = model.decode_step(params, cur, cache)
            outs.append(logits)
            cur = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        return jnp.stack(outs)

    # NOTE: ring caches are decode-only; prefill in the ring model processes
    # the prompt token-by-token.
    def run_ring(model):
        cache = model.init_cache(B, max_len)
        logits = None
        for t in range(T_prompt):
            logits, cache = model.decode_step(params, toks[:, t:t + 1], cache)
        outs = [logits]
        cur = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        for _ in range(n_new):
            logits, cache = model.decode_step(params, cur, cache)
            outs.append(logits)
            cur = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        return jnp.stack(outs)

    def run_full_stepwise(model):
        cache = model.init_cache(B, max_len)
        logits = None
        for t in range(T_prompt):
            logits, cache = model.decode_step(params, toks[:, t:t + 1], cache)
        outs = [logits]
        cur = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        for _ in range(n_new):
            logits, cache = model.decode_step(params, cur, cache)
            outs.append(logits)
            cur = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        return jnp.stack(outs)

    out_full = run_full_stepwise(full)
    out_ring = run_ring(ring)
    np.testing.assert_allclose(
        np.asarray(out_ring), np.asarray(out_full), rtol=2e-4, atol=2e-4
    )


def test_ring_cache_is_window_sized():
    cfg = get_config("recurrentgemma-2b", smoke=True)
    ring = build_model(cfg, ring_local=True)
    full = build_model(cfg)
    big = 4096
    c_ring = ring.init_cache(1, big)
    c_full = full.init_cache(1, big)
    b_ring = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(c_ring))
    b_full = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(c_full))
    assert b_ring < b_full / 50, (b_ring, b_full)
