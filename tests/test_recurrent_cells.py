"""Cell-level correctness for the recurrent mixers.

- RG-LRU: the associative-scan implementation must match a step-by-step
  sequential recurrence, and chunked prefill (carrying state) must equal
  one-shot prefill.
- mLSTM/sLSTM: streaming one token at a time through the cache must equal
  the full-sequence scan (the basis of the long_500k decode claim).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.models import rglru as rg
from repro.models import xlstm as xl
from repro.models.common import ParamBuilder


@pytest.fixture(scope="module")
def rg_setup():
    cfg = get_config("recurrentgemma-2b", smoke=True)
    pb = ParamBuilder(key=jax.random.PRNGKey(0))
    params = {k: v[0] for k, v in rg.init_rglru_block(pb, cfg).items()}
    return cfg, params


def _rglru_sequential(params, xs, cfg):
    """Literal per-step reference of the RG-LRU recurrence."""
    f32 = jnp.float32
    gate = jax.nn.gelu(xs @ params["w_gate"].astype(f32), approximate=True)
    u = xs @ params["w_x"].astype(f32)
    cw = cfg.conv_width
    prev = jnp.zeros((xs.shape[0], cw - 1, u.shape[-1]), f32)
    xp = jnp.concatenate([prev, u], axis=1)
    conv = sum(xp[:, i: i + u.shape[1], :] * params["conv"][i][None, None]
               for i in range(cw)) + params["conv_b"][None, None]
    B, T, W = conv.shape
    h = jnp.zeros((B, W), f32)
    outs = []
    for t in range(T):
        x_t = conv[:, t]
        r = jax.nn.sigmoid(x_t @ params["wa"] + params["ba"])
        i = jax.nn.sigmoid(x_t @ params["wi"] + params["bi"])
        log_a = -8.0 * jax.nn.softplus(params["lam"])[None, :] * r
        a = jnp.exp(log_a)
        h = a * h + jnp.sqrt(jnp.clip(1 - jnp.exp(2 * log_a), 0.0)) * (i * x_t)
        outs.append(h)
    hs = jnp.stack(outs, axis=1)
    return (hs * gate) @ params["w_down"].astype(f32)


def test_rglru_assoc_scan_matches_sequential(rg_setup):
    cfg, params = rg_setup
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, cfg.d_model)) * 0.5
    y_fast, _ = rg.rglru_block(params, x, cfg)
    y_ref = _rglru_sequential(params, x.astype(jnp.float32), cfg)
    np.testing.assert_allclose(np.asarray(y_fast), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)


def test_rglru_chunked_prefill_equals_oneshot(rg_setup):
    cfg, params = rg_setup
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 16, cfg.d_model)) * 0.5
    y_full, _ = rg.rglru_block(params, x, cfg)
    state = rg.init_rglru_state(cfg, 2)
    y1, state = rg.rglru_block(params, x[:, :7], cfg, state)
    y2, state = rg.rglru_block(params, x[:, 7:], cfg, state)
    y_chunked = jnp.concatenate([y1, y2], axis=1)
    np.testing.assert_allclose(np.asarray(y_chunked), np.asarray(y_full),
                               rtol=2e-4, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(T=st.integers(2, 24), seed=st.integers(0, 100))
def test_property_rglru_state_streaming(T, seed, ):
    cfg = get_config("recurrentgemma-2b", smoke=True)
    pb = ParamBuilder(key=jax.random.PRNGKey(3))
    params = {k: v[0] for k, v in rg.init_rglru_block(pb, cfg).items()}
    x = jax.random.normal(jax.random.PRNGKey(seed), (1, T, cfg.d_model)) * 0.3
    y_full, _ = rg.rglru_block(params, x, cfg)
    state = rg.init_rglru_state(cfg, 1)
    ys = []
    for t in range(T):  # token-by-token decode
        y_t, state = rg.rglru_block(params, x[:, t:t + 1], cfg, state)
        ys.append(y_t)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate(ys, axis=1)), np.asarray(y_full),
        rtol=5e-4, atol=5e-4,
    )


@pytest.mark.parametrize("cell,init_state", [
    (xl.mlstm, xl.init_mlstm_state),
    (xl.slstm, xl.init_slstm_state),
])
def test_xlstm_streaming_matches_full(cell, init_state):
    cfg = get_config("xlstm-350m", smoke=True)
    pb = ParamBuilder(key=jax.random.PRNGKey(4))
    init_fn = xl.init_mlstm if cell is xl.mlstm else xl.init_slstm
    params = {k: v[0] for k, v in init_fn(pb, cfg).items()}
    T = 10
    x = jax.random.normal(jax.random.PRNGKey(5), (2, T, cfg.d_model)) * 0.5
    y_full, _ = cell(params, x, cfg, init_state(cfg, 2))
    state = init_state(cfg, 2)
    ys = []
    for t in range(T):
        y_t, state = cell(params, x[:, t:t + 1], cfg, state)
        ys.append(y_t)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate(ys, axis=1)), np.asarray(y_full),
        rtol=1e-4, atol=1e-4,
    )


def test_mlstm_state_shape_constant_in_T():
    cfg = get_config("xlstm-350m", smoke=True)
    s = xl.init_mlstm_state(cfg, 4)
    bytes_ = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(s))
    # matrix state (B,H,dh,dh)+(B,H,dh)+(B,H): independent of any seq length
    assert bytes_ < 4 * cfg.n_heads * (64 ** 2 + 64 + 1) * 4 * 4
