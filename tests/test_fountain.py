"""Unit + property tests for the LT/fountain coding layer."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import fountain


def test_ideal_soliton_is_distribution():
    p = fountain.ideal_soliton(64)
    assert p.shape == (64,)
    assert np.all(p >= 0)
    np.testing.assert_allclose(p.sum(), 1.0, rtol=1e-12)


def test_robust_soliton_is_distribution():
    for R in (2, 8, 100, 1000):
        p = fountain.robust_soliton(R)
        assert p.shape == (R,)
        assert np.all(p >= -1e-15)
        np.testing.assert_allclose(p.sum(), 1.0, rtol=1e-12)


def test_code_structure_systematic():
    code = fountain.make_lt_code(R=16, K=6, seed=3)
    assert code.n_coded == 22
    degs = code.degrees()
    # systematic prefix has degree exactly 1, identity neighbours
    assert np.all(degs[:16] == 1)
    assert np.array_equal(code.idx[:16, 0], np.arange(16))
    # parities have degree >= 2 (degree-1 parities are resampled)
    assert np.all(degs[16:] >= 2)


def test_coverage_guarantee():
    # every source must appear in at least one parity when K > 0
    for seed in range(10):
        code = fountain.make_lt_code(R=24, K=4, seed=seed)
        par_rows = code.idx[24:][code.mask[24:]]
        assert set(range(24)) <= set(par_rows.tolist())


def test_encode_matches_dense_generator():
    code = fountain.make_lt_code(R=12, K=5, seed=1)
    x = jax.random.normal(jax.random.PRNGKey(0), (12, 7))
    coded = fountain.encode(x, code)
    G = jnp.asarray(code.dense_generator())
    np.testing.assert_allclose(np.asarray(coded), np.asarray(G @ x), rtol=1e-5)


def test_decode_identity_when_nothing_lost():
    code = fountain.make_lt_code(R=10, K=3, seed=2)
    x = jax.random.normal(jax.random.PRNGKey(1), (10, 4))
    coded = fountain.encode(x, code)
    ids = np.arange(13)
    dec, method = fountain.decode(coded, code, ids)
    assert method == "peel"
    np.testing.assert_allclose(np.asarray(dec), np.asarray(x), atol=1e-5)


@pytest.mark.parametrize("n_lost", [1, 2, 3])
def test_decode_recovers_after_losses(n_lost):
    code = fountain.make_lt_code(R=20, K=8, seed=5)
    x = jax.random.normal(jax.random.PRNGKey(2), (20, 3, 2))
    coded = fountain.encode(x, code)
    rng = np.random.default_rng(n_lost)
    lost = rng.choice(20, size=n_lost, replace=False)  # lose systematic blocks
    keep = np.setdiff1d(np.arange(28), lost)
    dec, _ = fountain.decode(coded[keep], code, keep)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(x), atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    R=st.integers(min_value=4, max_value=40),
    K_frac=st.floats(min_value=0.2, max_value=0.6),
    seed=st.integers(min_value=0, max_value=1000),
    data=st.data(),
)
def test_property_decode_inverts_encode(R, K_frac, seed, data):
    """Any loss pattern of <= K/2 blocks must decode exactly (peel or dense)."""
    K = max(2, int(R * K_frac))
    code = fountain.make_lt_code(R=R, K=K, seed=seed)
    n_lost = data.draw(st.integers(min_value=0, max_value=K // 2))
    rng = np.random.default_rng(seed + 1)
    lost = rng.choice(R + K, size=n_lost, replace=False)
    keep = np.setdiff1d(np.arange(R + K), lost)
    x = jax.random.normal(jax.random.PRNGKey(seed), (R, 3))
    coded = fountain.encode(x, code)
    try:
        dec, _ = fountain.decode(coded[keep], code, keep)
    except ValueError:
        # rank-deficient loss pattern: legal for a fountain code — the
        # contract is probabilistic; just skip (rate tracked separately).
        return
    np.testing.assert_allclose(np.asarray(dec), np.asarray(x), atol=1e-3)


def test_peel_plan_none_when_undecodable():
    code = fountain.make_lt_code(R=8, K=0, seed=0)
    # lose a systematic block with no parity: must stall
    keep = np.setdiff1d(np.arange(8), [3])
    assert fountain.peel_decode_plan(code, keep) is None


def test_failure_prob_small_for_modest_loss():
    p = fountain.decode_failure_prob(R=64, K=16, n_lost=4, trials=50, seed=0)
    # peeling may stall on small codes (falls back to dense solve), but true
    # unrecoverability must be rare
    assert p["unrecoverable"] <= 0.05
    assert p["peel_stall"] <= 0.5
