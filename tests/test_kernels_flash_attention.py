"""Shape/dtype/variant sweeps: Pallas flash attention vs. pure-jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention import attention_ref, flash_attention

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def _qkv(key, B, Hq, Hkv, Tq, Tk, D, dtype):
    kq, kk, kv = jax.random.split(key, 3)
    q = (jax.random.normal(kq, (B, Hq, Tq, D), jnp.float32) * 0.5).astype(dtype)
    k = (jax.random.normal(kk, (B, Hkv, Tk, D), jnp.float32) * 0.5).astype(dtype)
    v = jax.random.normal(kv, (B, Hkv, Tk, D), jnp.float32).astype(dtype)
    return q, k, v


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,Hq,Hkv,Tq,Tk,D,bq,bk",
    [
        (1, 2, 2, 32, 32, 16, 16, 16),     # MHA
        (2, 4, 2, 64, 64, 32, 32, 16),     # GQA group 2
        (1, 8, 1, 64, 64, 32, 16, 32),     # MQA
        (1, 2, 2, 128, 128, 64, 128, 128), # MXU-aligned
        (2, 2, 1, 48, 96, 16, 16, 16),     # Tk > Tq (prefix cache)
    ],
)
def test_flash_causal_sweep(B, Hq, Hkv, Tq, Tk, D, bq, bk, dtype):
    q, k, v = _qkv(jax.random.PRNGKey(0), B, Hq, Hkv, Tq, Tk, D, dtype)
    off = Tk - Tq
    ref = attention_ref(q, k, v, causal=True, q_offset=off)
    out = flash_attention(
        q, k, v, causal=True, q_offset=off,
        use_pallas=True, interpret=True, bq=bq, bk=bk,
    )
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        rtol=TOL[dtype], atol=TOL[dtype] * 8,
    )


@pytest.mark.parametrize("window", [8, 16, 64])
def test_flash_sliding_window(window):
    q, k, v = _qkv(jax.random.PRNGKey(1), 1, 2, 2, 64, 64, 32, jnp.float32)
    ref = attention_ref(q, k, v, causal=True, window=window)
    out = flash_attention(
        q, k, v, causal=True, window=window,
        use_pallas=True, interpret=True, bq=16, bk=16,
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-4)


@pytest.mark.parametrize("softcap", [10.0, 30.0, 50.0])
def test_flash_softcap(softcap):
    """gemma2-style logit soft-capping."""
    q, k, v = _qkv(jax.random.PRNGKey(2), 1, 2, 1, 32, 32, 16, jnp.float32)
    ref = attention_ref(q, k, v, causal=True, softcap=softcap)
    out = flash_attention(
        q, k, v, causal=True, softcap=softcap,
        use_pallas=True, interpret=True, bq=16, bk=16,
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-4)


def test_flash_noncausal():
    q, k, v = _qkv(jax.random.PRNGKey(3), 2, 2, 2, 32, 48, 16, jnp.float32)
    ref = attention_ref(q, k, v, causal=False)
    out = flash_attention(
        q, k, v, causal=False, use_pallas=True, interpret=True, bq=16, bk=16
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-4)


def test_flash_unaligned_lengths_padding():
    """Tq/Tk not multiples of the block sizes exercise the padding path."""
    q, k, v = _qkv(jax.random.PRNGKey(4), 1, 2, 2, 37, 53, 16, jnp.float32)
    ref = attention_ref(q, k, v, causal=True, q_offset=53 - 37)
    out = flash_attention(
        q, k, v, causal=True, q_offset=53 - 37,
        use_pallas=True, interpret=True, bq=16, bk=16,
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-4)


def test_flash_decode_shape():
    """Tq=1 against a long KV cache (the serve_step shape)."""
    q, k, v = _qkv(jax.random.PRNGKey(5), 4, 8, 2, 1, 256, 32, jnp.float32)
    ref = attention_ref(q, k, v, causal=True, q_offset=255)
    out = flash_attention(
        q, k, v, causal=True, q_offset=255,
        use_pallas=True, interpret=True, bq=16, bk=64,
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-4)


@settings(max_examples=8, deadline=None)
@given(
    Tq=st.integers(8, 48),
    extra=st.integers(0, 32),
    Hkv=st.sampled_from([1, 2]),
    group=st.sampled_from([1, 2, 4]),
    window=st.sampled_from([None, 8, 32]),
    seed=st.integers(0, 50),
)
def test_property_flash_matches_ref(Tq, extra, Hkv, group, window, seed):
    Tk = Tq + extra
    q, k, v = _qkv(jax.random.PRNGKey(seed), 1, Hkv * group, Hkv, Tq, Tk, 16,
                   jnp.float32)
    ref = attention_ref(q, k, v, causal=True, window=window, q_offset=extra)
    out = flash_attention(
        q, k, v, causal=True, window=window, q_offset=extra,
        use_pallas=True, interpret=True, bq=16, bk=16,
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=3e-5, atol=3e-4)
