"""Fleet-engine tests: the equivalence spine, queue laws, admission, and
the contention observables.

The load-bearing guarantee of PR 7 is the *equivalence spine*: a 1-task
fleet with the full dedicated pool IS the single-task engine, bit for
bit, for every registered policy — including the decoder-in-the-loop and
churn paths.  Everything else (disciplines, placements, metrics) is
pinned by construction laws:

  * work conservation — ``busy_end - busy == served demand + idle`` on
    every helper under every discipline;
  * single-job reduction — each discipline collapses to the dedicated
    recurrence ``start = max(arrive, busy)``, bitwise;
  * the golden files of PR 3 pin ``run_fleet`` transitively through the
    spine (re-checked here directly against tests/golden/).
"""

import json
import pathlib

import dataclasses
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine, fleet, policies, simulator
from repro.core.policies.ccp import CCPPolicy

ENG = engine.Engine()

GOLDEN = json.loads(
    (pathlib.Path(__file__).parent / "golden" / "policy_equivalence.json")
    .read_text()
)

CHURN = simulator.ChurnConfig(
    period=5.0, p_down=0.15, p_slow=0.25, drop_prob=0.05,
    ge_p_bad=0.03, ge_p_good=0.25, ge_loss_bad=0.5,
    p_cell=0.05, cell_frac=0.5, max_backoff=8.0)

# Fields whose single-task and task-0-of-fleet values must agree bitwise.
SPINE_FIELDS = ("T", "efficiency", "r_n", "valid", "max_backoff",
                "lost_frac")


def _bitwise(a, b):
    a, b = np.asarray(a), np.asarray(b)
    if a.dtype.kind == "f":
        return np.array_equal(a, b, equal_nan=True)
    return np.array_equal(a, b)


def _task0(single, fleet_res, field):
    a = np.asarray(single[field])
    b = np.asarray(fleet_res[field])
    return a, (b[:, 0] if b.ndim > a.ndim else b)


# ---------------------------------------------------------------------------
# The equivalence spine: fleet at n_tasks=1 == Engine.run, bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.fleet
@pytest.mark.parametrize("name", sorted(policies.names()))
def test_fleet_m1_equals_single_task_static(name):
    cfg = simulator.ScenarioConfig(N=8, scenario=1)
    keys = simulator.batch_keys(3)
    res1 = ENG.run(cfg, name, keys, 40)
    resf = ENG.run_fleet(cfg, name, keys, 40)
    assert resf.M == res1.M
    for f in SPINE_FIELDS:
        a, b = _task0(res1, resf, f)
        assert _bitwise(a, b), (name, f)
    # fleet bookkeeping at M=1: zero wait, perfectly fair by definition
    assert _bitwise(resf.sojourn, resf.T)
    assert np.asarray(resf.release).max() == 0.0
    fair = np.asarray(resf.fairness)
    assert np.allclose(fair[np.isfinite(fair)], 1.0)


@pytest.mark.fleet
@pytest.mark.parametrize(
    "name", ["ccp", "adaptive_rate_fb", "rateless_ccp", "hcmm",
             "naive_oracle"])
def test_fleet_m1_equals_single_task_churn(name):
    """The churn path adds the GE chain, phase outages, cell events and
    the timeout/backoff hooks — all shared step kernels; the spine must
    hold there too (decoder feedback included via rateless/adaptive_fb)."""
    cfg = simulator.ScenarioConfig(N=8, scenario=1, churn=CHURN)
    keys = simulator.batch_keys(3)
    res1 = ENG.run(cfg, name, keys, 40)
    resf = ENG.run_fleet(cfg, name, keys, 40)
    for f in SPINE_FIELDS:
        a, b = _task0(res1, resf, f)
        assert _bitwise(a, b), (name, f)


@pytest.mark.fleet
@pytest.mark.parametrize("name", sorted(GOLDEN))
def test_fleet_m1_matches_pre_redesign_golden(name):
    """run_fleet reproduces the PR-3 goldens directly (not just through
    Engine.run): the event-clock refactor did not move the physics."""
    g = GOLDEN[name]
    if name.startswith("static_sc1"):
        cfg, mode = (simulator.ScenarioConfig(N=20, scenario=1),
                     name.split("_")[-1])
    elif name.startswith("static_sc2"):
        cfg, mode = simulator.ScenarioConfig(N=20, scenario=2), "ccp"
    else:
        ch = simulator.ChurnConfig(
            period=5.0, p_down=0.1, p_slow=0.2, drop_prob=0.05,
            ge_p_bad=0.02, ge_p_good=0.2, ge_loss_bad=0.5,
            p_cell=0.1, cell_frac=0.5, outage_dist="lognormal",
            outage_mean=4.0, outage_sigma=0.5, max_backoff=8.0)
        cfg, mode = (simulator.ScenarioConfig(N=16, scenario=1, churn=ch),
                     name[len("churn_"):])
    keys = simulator.batch_keys(g["reps"], seed0=g.get("seed0", 0))
    res = ENG.run_fleet(cfg, policies.get(mode), keys, g["R"],
                        M_override=g["M"])
    assert res.M == g["M"]
    got = {f: _task0({f: np.asarray(g[f])}, res, f)[1]
           for f in ("T", "r_n", "efficiency", "valid") if f in g}
    assert _bitwise(np.float32(np.asarray(g["T"])), np.float32(got["T"]))
    assert _bitwise(np.asarray(g["r_n"]), got["r_n"])
    assert _bitwise(np.float32(np.asarray(g["efficiency"])),
                    np.float32(got["efficiency"]))
    assert _bitwise(np.asarray(g["valid"]), got["valid"])


# ---------------------------------------------------------------------------
# Queue laws: work conservation + single-job reduction
# ---------------------------------------------------------------------------

def _random_round(seed, T, N):
    rng = np.random.default_rng(seed)
    arrive = jnp.asarray(rng.uniform(0.0, 10.0, (T, N)).astype(np.float32))
    demand = jnp.asarray(rng.uniform(0.1, 3.0, (T, N)).astype(np.float32))
    active = jnp.asarray(rng.random((T, N)) < 0.7)
    busy = jnp.asarray(rng.uniform(0.0, 8.0, (N,)).astype(np.float32))
    key = jnp.asarray(rng.uniform(0.0, 1.0, (T, N)).astype(np.float32))
    return arrive, jnp.where(active, demand, 0.0), active, busy, key


@pytest.mark.fleet
@pytest.mark.parametrize("discipline", fleet.DISCIPLINES)
@pytest.mark.parametrize("seed,T", [(0, 1), (1, 3), (2, 5), (3, 8)])
def test_serve_round_work_conservation(discipline, seed, T):
    arrive, demand, active, busy, key = _random_round(seed, T, 6)
    start, fin, idle, busy_end = fleet.serve_round(
        arrive, demand, active, busy, key, discipline)
    start, fin, idle = map(np.asarray, (start, fin, idle))
    act = np.asarray(active)
    # the server is never idle with work queued; all demand is served
    np.testing.assert_allclose(
        np.asarray(busy_end) - np.asarray(busy),
        np.asarray(demand).sum(0) + idle.sum(0), rtol=1e-5)
    # inactive jobs do not exist
    assert (start[~act] == 0).all() and (fin[~act] == 0).all()
    assert (idle[~act] == 0).all()
    # causality: nothing starts before it arrives (or before the carried
    # busy time frees the server for the non-preemptive disciplines)
    assert (start[act] >= np.asarray(arrive)[act] - 1e-5).all()
    if discipline != "ps":
        np.testing.assert_allclose(
            fin[act], start[act] + np.asarray(demand)[act], rtol=1e-6)
    else:
        assert (fin[act] >= start[act] + np.asarray(demand)[act] - 1e-4).all()


@pytest.mark.fleet
@pytest.mark.parametrize("discipline", fleet.DISCIPLINES)
def test_serve_round_single_job_reduces_to_dedicated_recurrence(discipline):
    """The T=1 bitwise reduction behind the equivalence spine."""
    rng = np.random.default_rng(7)
    arrive = jnp.asarray(rng.uniform(0, 5, (1, 16)).astype(np.float32))
    demand = jnp.asarray(rng.uniform(0.1, 2, (1, 16)).astype(np.float32))
    busy = jnp.asarray(rng.uniform(0, 5, (16,)).astype(np.float32))
    ones = jnp.ones((1, 16), bool)
    start, fin, idle, busy_end = fleet.serve_round(
        arrive, demand, ones, busy, arrive, discipline)
    want_start = jnp.maximum(arrive[0], busy)
    assert _bitwise(start[0], want_start)
    assert _bitwise(fin[0], want_start + demand[0])
    assert _bitwise(idle[0], jnp.maximum(arrive[0] - busy, 0.0))
    assert _bitwise(busy_end, want_start + demand[0])


@pytest.mark.fleet
def test_priority_discipline_orders_same_round_jobs():
    """Two jobs waiting on one busy helper: priority serves the low key
    first regardless of arrival order; fifo serves the earlier arrival."""
    arrive = jnp.asarray([[0.0], [0.1]])
    demand = jnp.asarray([[1.0], [1.0]])
    active = jnp.ones((2, 1), bool)
    busy = jnp.asarray([5.0])  # both queued long before the server frees
    prio = jnp.asarray([[1.0], [0.0]])  # task 1 outranks task 0
    s_f, *_ = fleet.serve_round(arrive, demand, active, busy, arrive, "fifo")
    s_p, *_ = fleet.serve_round(arrive, demand, active, busy, prio, "priority")
    assert float(s_f[0, 0]) < float(s_f[1, 0])
    assert float(s_p[1, 0]) < float(s_p[0, 0])


@pytest.mark.fleet
def test_ps_stretches_concurrent_jobs():
    """Two equal jobs entering an idle helper together each see 2x their
    solo service time under egalitarian sharing."""
    arrive = jnp.zeros((2, 1))
    demand = jnp.full((2, 1), 3.0)
    active = jnp.ones((2, 1), bool)
    start, fin, idle, busy_end = fleet.serve_round(
        arrive, demand, active, jnp.zeros(1), arrive, "ps")
    np.testing.assert_allclose(np.asarray(fin), 6.0, rtol=1e-6)
    np.testing.assert_allclose(float(busy_end[0]), 6.0, rtol=1e-6)


# ---------------------------------------------------------------------------
# Admission / placement
# ---------------------------------------------------------------------------

@pytest.mark.fleet
def test_striped_placement_is_disjoint_until_pool_exhausted():
    cfg = simulator.ScenarioConfig(N=12, scenario=1)
    fc = fleet.FleetConfig(n_tasks=3, placement="striped",
                           helpers_per_task=4)
    mu = jnp.ones(12)
    recruit, prio = fleet.place(jax.random.PRNGKey(0), fc, cfg, mu, mu, mu)
    r = np.asarray(recruit)
    assert r.shape == (3, 12)
    assert (r.sum(axis=1) == 4).all()
    assert (r.sum(axis=0) <= 1).all()          # disjoint: 3*4 <= 12
    assert _bitwise(prio, jnp.arange(3, dtype=jnp.float32))


@pytest.mark.fleet
def test_fastest_placement_targets_highest_service_rate():
    cfg = simulator.ScenarioConfig(N=6, scenario=1)
    fc = fleet.FleetConfig(n_tasks=2, placement="fastest",
                           helpers_per_task=2)
    mu = jnp.asarray([1.0, 10.0, 1.0, 20.0, 1.0, 1.0])
    a = jnp.full(6, 0.01)
    recruit, _ = fleet.place(jax.random.PRNGKey(0), fc, cfg, mu, a, mu)
    r = np.asarray(recruit)
    assert (r[0] == r[1]).all()                # shared hot set
    assert set(np.nonzero(r[0])[0]) == {1, 3}  # the two fast helpers


@pytest.mark.fleet
def test_random_placement_has_exact_recruit_count():
    cfg = simulator.ScenarioConfig(N=10, scenario=1)
    fc = fleet.FleetConfig(n_tasks=4, placement="random",
                           helpers_per_task=3)
    mu = jnp.ones(10)
    recruit, _ = fleet.place(jax.random.PRNGKey(1), fc, cfg, mu, mu, mu)
    assert (np.asarray(recruit).sum(axis=1) == 3).all()


@pytest.mark.fleet
def test_block_policies_reallocate_over_recruit_set():
    """Fixed-allocation block policies (fleet_aux='per_task') must land
    their whole load on each tenant's recruited helpers — a block stranded
    on a stopped stream would make the task structurally unfinishable."""
    cfg = simulator.ScenarioConfig(N=12, scenario=1)
    mu, a, rate = simulator.draw_helpers(jax.random.PRNGKey(3), cfg)
    recruit = jnp.stack([jnp.arange(12) < 4, jnp.arange(12) >= 8])
    for name in ("hcmm", "uncoded_mean", "uncoded_mu"):
        pol = policies.get(name)
        aux = pol.prepare_fleet(cfg, 100, cfg.ccp_cfg(100), mu, a, rate,
                                recruit)
        loads = np.asarray(aux["loads"])
        assert loads.shape == (2, 12), name
        assert (loads[~np.asarray(recruit)] == 0).all(), name
        assert (loads.sum(axis=1) >= 100).all(), (name, loads)
    # end-to-end: hcmm under a striped partial recruit actually completes
    fc = fleet.FleetConfig(n_tasks=3, placement="striped",
                           helpers_per_task=4)
    res = ENG.run_fleet(cfg, "hcmm", simulator.batch_keys(2), 120, fleet=fc)
    assert np.asarray(res.valid).all()
    assert np.isfinite(np.asarray(res.sojourn)).all()


@pytest.mark.fleet
def test_register_placement_round_trips():
    @fleet.register_placement("_test_rule")
    def _rule(key, fc, cfg, mu, a, rate):
        return jnp.ones((fc.n_tasks, cfg.N), bool)

    try:
        cfg = simulator.ScenarioConfig(N=4, scenario=1)
        fc = fleet.FleetConfig(n_tasks=2, placement="_test_rule")
        mu = jnp.ones(4)
        recruit, _ = fleet.place(jax.random.PRNGKey(0), fc, cfg, mu, mu, mu)
        assert np.asarray(recruit).all()
    finally:
        del fleet.PLACEMENTS["_test_rule"]


@pytest.mark.fleet
def test_release_processes():
    k = jax.random.PRNGKey(0)
    assert (np.asarray(fleet.draw_releases(
        k, fleet.FleetConfig(n_tasks=4))) == 0).all()
    uni = np.asarray(fleet.draw_releases(
        k, fleet.FleetConfig(n_tasks=4, arrival="uniform", load=2.0)))
    np.testing.assert_allclose(uni, [0.0, 0.5, 1.0, 1.5])
    poi = np.asarray(fleet.draw_releases(
        k, fleet.FleetConfig(n_tasks=5, arrival="poisson", load=1.0)))
    assert poi[0] == 0.0 and (np.diff(poi) > 0).all()


# ---------------------------------------------------------------------------
# Input validation (satellite: actionable Engine.run errors)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bad_R", [0, -3, 1.5, True])
def test_run_rejects_bad_R(bad_R):
    cfg = simulator.ScenarioConfig(N=4, scenario=1)
    with pytest.raises((ValueError, TypeError), match="R must be"):
        ENG.run(cfg, "ccp", simulator.batch_keys(2), bad_R)


def test_run_rejects_empty_keys():
    cfg = simulator.ScenarioConfig(N=4, scenario=1)
    with pytest.raises(ValueError, match="batch_keys"):
        ENG.run(cfg, "ccp", jnp.zeros((0, 2), jnp.uint32), 10)


def test_run_rejects_unknown_policy_with_known_list():
    cfg = simulator.ScenarioConfig(N=4, scenario=1)
    with pytest.raises(ValueError) as e:
        ENG.run(cfg, "cpp", simulator.batch_keys(2), 10)
    assert "ccp" in str(e.value)  # the known list is in the message


def test_run_rejects_non_policy_object():
    cfg = simulator.ScenarioConfig(N=4, scenario=1)
    with pytest.raises(TypeError, match="registry name or a Policy"):
        ENG.run(cfg, 42, simulator.batch_keys(2), 10)


def test_fleet_config_validation():
    with pytest.raises(ValueError, match="discipline"):
        fleet.FleetConfig(discipline="lifo")
    with pytest.raises(ValueError, match="arrival"):
        fleet.FleetConfig(arrival="bursty")
    with pytest.raises(ValueError, match="load"):
        fleet.FleetConfig(arrival="poisson")
    with pytest.raises(ValueError, match="n_tasks"):
        fleet.FleetConfig(n_tasks=0)
    with pytest.raises(ValueError, match="priority"):
        fleet.FleetConfig(n_tasks=2, priority=(1.0,))
    with pytest.raises(ValueError, match="placement"):
        cfg = simulator.ScenarioConfig(N=4, scenario=1)
        fc = fleet.FleetConfig(placement="nearest")
        fleet.place(jax.random.PRNGKey(0), fc, cfg,
                    jnp.ones(4), jnp.ones(4), jnp.ones(4))
    with pytest.raises(ValueError, match="discipline"):
        z = jnp.zeros((1, 2))
        fleet.serve_round(z, z, z > 0, jnp.zeros(2), z, "lifo")


# ---------------------------------------------------------------------------
# Input validation: run_fleet (satellite of the transport PR)
# ---------------------------------------------------------------------------

def test_run_fleet_rejects_non_fleetconfig():
    cfg = simulator.ScenarioConfig(N=4, scenario=1)
    with pytest.raises(TypeError, match="FleetConfig"):
        ENG.run_fleet(cfg, "ccp", simulator.batch_keys(2), 10,
                      fleet={"n_tasks": 2})


def test_run_fleet_rejects_unknown_placement_with_known_list():
    cfg = simulator.ScenarioConfig(N=4, scenario=1)
    fc = fleet.FleetConfig(n_tasks=2, placement="nearest")
    with pytest.raises(ValueError) as e:
        ENG.run_fleet(cfg, "ccp", simulator.batch_keys(2), 10, fleet=fc)
    msg = str(e.value)
    assert "nearest" in msg and "striped" in msg and "register" in msg


def test_run_fleet_rejects_oversubscribed_recruitment():
    cfg = simulator.ScenarioConfig(N=4, scenario=1)
    fc = fleet.FleetConfig(n_tasks=2, helpers_per_task=9)
    with pytest.raises(ValueError, match="helpers_per_task"):
        ENG.run_fleet(cfg, "ccp", simulator.batch_keys(2), 10, fleet=fc)


def test_run_fleet_shares_run_validation():
    """run_fleet goes through the same R / keys / policy checks as run."""
    cfg = simulator.ScenarioConfig(N=4, scenario=1)
    with pytest.raises((ValueError, TypeError), match="R must be"):
        ENG.run_fleet(cfg, "ccp", simulator.batch_keys(2), 0)
    with pytest.raises(ValueError, match="batch_keys"):
        ENG.run_fleet(cfg, "ccp", jnp.zeros((0, 2), jnp.uint32), 10)
    with pytest.raises(ValueError) as e:
        ENG.run_fleet(cfg, "cpp", simulator.batch_keys(2), 10)
    assert "ccp" in str(e.value)


# ---------------------------------------------------------------------------
# Sharded fleet batch (satellite: run_fleet(shard=True))
# ---------------------------------------------------------------------------

def test_run_fleet_shard_single_device_matches_vmap():
    """shard=True on one device must still be bitwise the vmap path (the
    mesh is degenerate but the shard_map machinery is exercised, padding
    included: 3 reps on 1 device)."""
    cfg = simulator.ScenarioConfig(N=6, scenario=1, churn=CHURN)
    fc = fleet.FleetConfig(n_tasks=2, placement="striped",
                           helpers_per_task=4)
    keys = simulator.batch_keys(3)
    r_vmap = ENG.run_fleet(cfg, "ccp", keys, 30, fleet=fc)
    r_shard = ENG.run_fleet(cfg, "ccp", keys, 30, fleet=fc, shard=True)
    for f in SPINE_FIELDS + ("sojourn", "release", "fairness"):
        assert _bitwise(r_vmap[f], r_shard[f]), f


@pytest.mark.multidevice
def test_run_fleet_shard_multidevice_matches_vmap():
    """8 host devices: the sharded fleet batch is bitwise the vmap batch,
    including a batch size that does not divide the device count."""
    import subprocess
    import sys
    import textwrap

    script = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + \
            " --xla_force_host_platform_device_count=8"
        import numpy as np
        from repro.core import engine, fleet, simulator

        eng = engine.Engine()
        ch = simulator.ChurnConfig(
            period=5.0, p_down=0.15, p_slow=0.25, drop_prob=0.05,
            ge_p_bad=0.03, ge_p_good=0.25, ge_loss_bad=0.5,
            rtt_dist="fixed", rtt_mean=0.5, max_backoff=8.0)
        cfg = simulator.ScenarioConfig(N=6, scenario=1, churn=ch)
        fc = fleet.FleetConfig(n_tasks=3, placement="striped",
                               helpers_per_task=3)
        keys = simulator.batch_keys(11)  # deliberately not a multiple of 8
        a = eng.run_fleet(cfg, "ccp", keys, 30, fleet=fc)
        b = eng.run_fleet(cfg, "ccp", keys, 30, fleet=fc, shard=True)
        for f in ("T", "efficiency", "r_n", "valid", "max_backoff",
                  "lost_frac", "sojourn", "release", "fairness"):
            x, y = np.asarray(a[f]), np.asarray(b[f])
            assert x.shape == y.shape, (f, x.shape, y.shape)
            assert np.array_equal(x, y, equal_nan=(x.dtype.kind == "f")), f
        print("SHARD-OK")
        """
    )
    import os
    proc = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": "src"},
        cwd=str(pathlib.Path(__file__).parent.parent), timeout=900)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "SHARD-OK" in proc.stdout


# ---------------------------------------------------------------------------
# Contention observables reach the policy hooks
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class _ProbeCCP(CCPPolicy):
    """ccp plus a recorder: folds the queue-delay / contention fields of
    StepCtx into the policy state, proving the observables reach the
    hooks (and flow out through RunResult extras)."""

    name = "_probe_ccp"

    def init(self, n):
        return dict(super().init(n),
                    probe_qd=jnp.zeros(n), probe_ct=jnp.zeros(n))

    def on_computed(self, state, ctx):
        state = super().on_computed(state, ctx)
        qd = ctx.queue_delay if ctx.queue_delay is not None else 0.0
        ct = ctx.contention if ctx.contention is not None else 0.0
        return dict(state,
                    probe_qd=jnp.maximum(state["probe_qd"], qd),
                    probe_ct=jnp.maximum(state["probe_ct"], ct))

    def summary(self, state):
        return dict(super().summary(state),
                    probe_qd=state["probe_qd"].max(),
                    probe_ct=state["probe_ct"].max())


@pytest.mark.fleet
def test_fleet_exposes_queue_delay_and_contention_to_hooks():
    cfg = simulator.ScenarioConfig(N=6, scenario=1)
    fc = fleet.FleetConfig(n_tasks=3, discipline="fifo", placement="all")
    res = ENG.run_fleet(cfg, _ProbeCCP(), simulator.batch_keys(2), 30,
                        fleet=fc)
    # 3 tenants all recruiting all 6 helpers: round 0 alone queues 3 jobs
    # on every helper, so both observables must be strictly positive.
    assert np.asarray(res.extras["probe_ct"]).max() >= 2
    assert np.asarray(res.extras["probe_qd"]).max() > 0
    # and the single-task engine leaves them at their None defaults
    res1 = ENG.run(cfg, _ProbeCCP(), simulator.batch_keys(2), 30)
    assert np.asarray(res1.extras["probe_qd"]).max() == 0


# ---------------------------------------------------------------------------
# Fleet behaviour under load
# ---------------------------------------------------------------------------

@pytest.mark.fleet
def test_contention_degrades_completion_time():
    """4 tenants sharing the full pool must finish (p50 sojourn) no
    faster than a lone tenant on the same pool — and the load must
    actually bite (strictly slower)."""
    cfg = simulator.ScenarioConfig(N=6, scenario=1)
    keys = simulator.batch_keys(3)
    lone = ENG.run_fleet(cfg, "ccp", keys, 40)
    packed = ENG.run_fleet(cfg, "ccp", keys, 40,
                           fleet=fleet.FleetConfig(n_tasks=4))
    assert packed.summary()["p50"] > lone.summary()["p50"] * 1.2
    # shared pool, equal tenants: fairness stays high
    assert np.nanmean(np.asarray(packed.fairness)) > 0.5


@pytest.mark.fleet
def test_fleet_metrics_shapes_and_ranges():
    cfg = simulator.ScenarioConfig(N=6, scenario=1)
    fc = fleet.FleetConfig(n_tasks=3, discipline="ps", placement="striped",
                           helpers_per_task=3)
    res = ENG.run_fleet(cfg, "ccp", simulator.batch_keys(2), 30, fleet=fc)
    assert res.n_tasks == 3 and res.discipline == "ps"
    assert res.T.shape == (2, 3)
    assert res.util.shape == (2, 6)
    u = np.asarray(res.util)
    assert (u >= 0).all() and (u <= 1.0 + 1e-5).all()
    f = np.asarray(res.fairness)
    assert ((f > 1 / 3 - 1e-6) & (f <= 1 + 1e-6))[np.isfinite(f)].all()
    s = res.summary()
    assert s["p99"] >= s["p50"] > 0
