"""Tests for the logical-axis sharding rules (divisibility, fallbacks,
conflict resolution, ZeRO-1)."""

import numpy as np
import pytest

jax = pytest.importorskip("jax")
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.parallel import sharding as shd


class FakeMesh:
    """Stand-in with the production axis sizes (no real devices needed)."""

    def __init__(self, shape, names):
        self.devices = np.empty(shape, dtype=object)
        self.axis_names = names


MESH1 = FakeMesh((16, 16), ("data", "model"))
MESH2 = FakeMesh((2, 16, 16), ("pod", "data", "model"))


def test_heads_shard_when_divisible():
    cfg = get_config("gemma2-27b")       # 32 heads % 16 == 0
    rules = shd.make_rules(cfg, MESH1)
    assert rules["heads"] == "model"
    assert rules["vocab"] == "model"     # 256000 % 16 == 0
    assert rules["embed"] is None


@pytest.mark.parametrize("arch", ["phi4-mini-3.8b", "llava-next-34b",
                                  "whisper-large-v3", "recurrentgemma-2b"])
def test_embed_fallback_when_heads_dont_divide(arch):
    cfg = get_config(arch)
    rules = shd.make_rules(cfg, MESH1)
    assert rules["heads"] is None
    assert rules["embed"] == "model", f"{arch}: needs row-parallel fallback"


def test_moe_expert_parallel():
    cfg = get_config("qwen3-moe-235b-a22b")
    rules = shd.make_rules(cfg, MESH1)
    assert rules["experts"] == "model"
    # 235B: FSDP kicks in — expert ff dim sharded over data
    assert rules["ff"] == "data"
    cfg2 = get_config("moonshot-v1-16b-a3b")
    rules2 = shd.make_rules(cfg2, MESH1)
    assert rules2["experts"] == "model"
    assert rules2["ff"] == "model"       # small enough, no FSDP


def test_whisper_vocab_not_divisible_replicates():
    cfg = get_config("whisper-large-v3")  # 51866 % 16 != 0
    rules = shd.make_rules(cfg, MESH1)
    assert rules["vocab"] is None


def test_conflict_resolution_keeps_first():
    rules = {"vocab": "model", "embed": "model"}
    spec = shd.spec_for_axes(("vocab", "embed"), rules)
    assert spec == P("model", None)
    spec2 = shd.spec_for_axes(("embed", "vocab"), rules)
    assert spec2 == P("model", None)


def test_spec_for_axes_layers_never_sharded():
    cfg = get_config("gemma2-27b")
    rules = shd.make_rules(cfg, MESH1)
    spec = shd.spec_for_axes(("layers", "embed", "heads", "head_dim"), rules)
    assert spec[0] is None and spec[2] == "model"


def test_batch_spec_divisibility():
    mesh = FakeMesh((2, 16, 16), ("pod", "data", "model"))
    assert shd.batch_spec(mesh, 256)[0] == ("pod", "data")  # 256 % 32 == 0
    assert shd.batch_spec(mesh, 16)[0] == "data"            # only data fits
    assert shd.batch_spec(mesh, 1)[0] is None               # long_500k b=1


def test_every_arch_has_some_model_sharding():
    """No arch may end up fully replicated on the production mesh."""
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        rules = shd.make_rules(cfg, MESH1)
        assert any(v == "model" for v in rules.values()), (arch, rules)


def test_opt_state_zero1(tmp_path):
    """ZeRO-1: an unsharded-by-param dim gets the data axis when divisible."""
    import jax

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # pretend data axis is 16 by checking rule math via FakeMesh path:
    cfg = get_config("gemma2-27b")
    rules = shd.make_rules(cfg, MESH1)
    # an attention weight (embed, heads, head_dim): heads->model; ZeRO should
    # grab embed (4608 % 16 == 0) for the optimizer moments
    spec = shd.spec_for_axes(("embed", "heads", "head_dim"), rules)
    assert spec == P(None, "model", None)


def test_data_mesh_over_local_devices():
    """data_mesh builds the 1-D 'data' mesh the MC engine shards over."""
    import jax

    mesh = shd.data_mesh()
    assert mesh.axis_names == ("data",)
    assert mesh.devices.shape == (jax.local_device_count(),)
    sub = shd.data_mesh(jax.local_devices()[:1])
    assert sub.devices.shape == (1,)
    # batch divisible -> leading dim sharded over 'data'
    assert shd.batch_spec(sub, 4, extra_dims=1) == P("data", None)
