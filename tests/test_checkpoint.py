"""Tests for chunked/zstd/async checkpointing + reshard-on-restore."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ck
from repro.optim import adamw


def _tree(key):
    k1, k2 = jax.random.split(key)
    return {
        "layer": {"w": jax.random.normal(k1, (8, 16)),
                  "b": jnp.zeros(16, jnp.bfloat16)},
        "emb": jax.random.normal(k2, (32, 8)),
    }


def test_save_restore_roundtrip(tmp_path):
    t = _tree(jax.random.PRNGKey(0))
    ck.save(tmp_path, 5, t, metadata={"step": 5})
    target = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
    r, meta = ck.restore(tmp_path, 5, target)
    assert meta["step"] == 5
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restore_latest_and_gc(tmp_path):
    t = _tree(jax.random.PRNGKey(1))
    for s in (1, 2, 3, 4):
        ck.save(tmp_path, s, t, keep_last=2)
    assert ck.latest_step(tmp_path) == 4
    # gc kept only the last 2
    assert sorted(p.name for p in tmp_path.glob("step_*")) == [
        "step_000000003", "step_000000004"
    ]


def test_optimizer_state_roundtrip(tmp_path):
    params = _tree(jax.random.PRNGKey(2))
    state = adamw.init(params)
    tree = {"params": params, "opt": state}
    ck.save(tmp_path, 1, tree)
    target = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    r, _ = ck.restore(tmp_path, 1, target)
    assert int(r["opt"].step) == 0
    np.testing.assert_array_equal(
        np.asarray(r["params"]["layer"]["w"]), np.asarray(params["layer"]["w"])
    )


def test_async_checkpointer(tmp_path):
    t = _tree(jax.random.PRNGKey(3))
    acp = ck.AsyncCheckpointer(tmp_path)
    acp.save_async(7, t, metadata={"step": 7})
    acp.wait()
    assert ck.latest_step(tmp_path) == 7


def test_shape_mismatch_raises(tmp_path):
    t = _tree(jax.random.PRNGKey(4))
    ck.save(tmp_path, 1, t)
    bad = jax.tree.map(lambda x: jax.ShapeDtypeStruct((1,) + x.shape, x.dtype), t)
    with pytest.raises(ValueError):
        ck.restore(tmp_path, 1, bad)


def test_zlib_roundtrip_with_zstd_missing(tmp_path, monkeypatch):
    """A zlib-only build (no zstandard wheel) must round-trip its own
    checkpoints: zlib-written leaf files + codec recorded in the index."""
    from repro.checkpoint import checkpoint as ckm

    monkeypatch.setattr(ckm, "zstandard", None)
    monkeypatch.setattr(ckm, "DEFAULT_CODEC", "zlib")
    t = _tree(jax.random.PRNGKey(6))
    ck.save(tmp_path, 1, t)
    assert list(tmp_path.glob("step_*/*.zz")), "zlib leaves carry .zz"
    target = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
    r, _ = ck.restore(tmp_path, 1, target)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_zstd_checkpoint_without_wheel_raises_actionable_error(
        tmp_path, monkeypatch):
    """A zstd-written checkpoint read in a zlib-only environment must fail
    with one error naming the missing codec — not a deep decode traceback
    from trying the wrong decompressor on each leaf."""
    import types

    from repro.checkpoint import checkpoint as ckm

    class _FakeCompressor:
        def __init__(self, level=3):
            pass

        def compress(self, data):
            return data  # restore must fail before ever decoding a leaf

    monkeypatch.setattr(
        ckm, "zstandard", types.SimpleNamespace(ZstdCompressor=_FakeCompressor)
    )
    monkeypatch.setattr(ckm, "DEFAULT_CODEC", "zstd")
    t = _tree(jax.random.PRNGKey(7))
    ck.save(tmp_path, 1, t)
    assert list(tmp_path.glob("step_*/*.zst")), "zstd leaves carry .zst"

    monkeypatch.setattr(ckm, "zstandard", None)  # the zlib-only environment
    target = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
    with pytest.raises(RuntimeError, match="zstandard"):
        ck.restore(tmp_path, 1, target)


def test_corrupt_leaf_error_names_codec(tmp_path, monkeypatch):
    """A leaf that fails to decode reports the leaf, file and codec instead
    of surfacing the raw zlib.error."""
    from repro.checkpoint import checkpoint as ckm

    monkeypatch.setattr(ckm, "zstandard", None)
    monkeypatch.setattr(ckm, "DEFAULT_CODEC", "zlib")
    t = _tree(jax.random.PRNGKey(8))
    ck.save(tmp_path, 1, t)
    leaf = sorted(tmp_path.glob("step_*/*.zz"))[0]
    leaf.write_bytes(b"\x00not-zlib-data")
    target = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
    with pytest.raises(RuntimeError, match="zlib"):
        ck.restore(tmp_path, 1, target)


def test_restore_with_shardings(tmp_path):
    """Reshard-on-restore: restore onto an explicit device placement."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    t = {"w": jnp.arange(16.0).reshape(4, 4)}
    ck.save(tmp_path, 1, t)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    sh = {"w": NamedSharding(mesh, P(None, None))}
    target = {"w": jax.ShapeDtypeStruct((4, 4), jnp.float32)}
    r, _ = ck.restore(tmp_path, 1, target, sh)
    np.testing.assert_array_equal(np.asarray(r["w"]), np.asarray(t["w"]))
    assert r["w"].sharding == sh["w"]
