"""Dynamics engine tests: the vmapped batch runner and the churn model.

Covers the three contract points of the batched Monte-Carlo engine:
  (a) run_batch over vmapped keys == per-key sequential _run_mode,
  (b) a helper that dies mid-task gets exponentially backed-off TTI
      (Alg. 1 line 13) and the task completes from the survivors,
  (c) a zero-churn ChurnConfig reproduces the static paper model
      bit-for-bit (the dynamics machinery is numerically invisible
      when its knobs are neutral).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import simulator


CFG = simulator.ScenarioConfig(N=20, scenario=1)
R = 400


# ---------------------------------------------------------------------------
# (a) batch == sequential
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["ccp", "best", "naive"])
def test_run_batch_matches_sequential(mode):
    reps = 4
    keys = simulator.batch_keys(reps)
    batch = simulator.run_batch(keys, CFG, R, mode)
    for r in range(reps):
        # batch_keys(reps, seed0=0)[r] == PRNGKey(r)
        seq = simulator._run_mode(jax.random.PRNGKey(r), CFG, R, mode,
                                  M_override=batch["M"])
        np.testing.assert_allclose(batch["T"][r], seq["T"], rtol=1e-6)
        np.testing.assert_array_equal(batch["r_n"][r], seq["r_n"])
        np.testing.assert_allclose(
            batch["efficiency"][r], seq["efficiency"], rtol=1e-5
        )


def test_run_batch_matches_sequential_under_churn():
    cfg = simulator.ScenarioConfig(
        N=20, scenario=1,
        churn=simulator.ChurnConfig(period=5.0, p_down=0.1, p_slow=0.2,
                                    drop_prob=0.05),
    )
    keys = simulator.batch_keys(3)
    batch = simulator.run_batch(keys, cfg, R, "ccp")
    for r in range(3):
        seq = simulator._run_mode(jax.random.PRNGKey(r), cfg, R, "ccp",
                                  M_override=batch["M"])
        np.testing.assert_allclose(batch["T"][r], seq["T"], rtol=1e-6)
        np.testing.assert_array_equal(batch["r_n"][r], seq["r_n"])


# ---------------------------------------------------------------------------
# (b) mid-task death -> exponential backoff, completion from survivors
# ---------------------------------------------------------------------------

def test_dead_helper_backs_off_and_task_completes():
    """Helper 0 is up in phase 0 only, then down for good (period=4s).  Its
    TTI backoff must double per timeout up to the cap (Alg. 1 l.13) while the
    survivors keep streaming at backoff 1, and the (R+K)-th order statistic
    must still be reached from the survivors alone."""
    N, M, period, cap = 3, 64, 4.0, 8.0
    beta = jnp.full((N, M), 1.0)
    d_up = jnp.full((N, M), 0.01)
    d_ack = jnp.full((N, M), 0.001)
    d_down = jnp.full((N, M), 0.01)
    # The phase schedule wraps after n_phases*period seconds (rejoin is the
    # wrap's purpose — tested below); here the death must be final, so make
    # the wrap horizon far exceed the backed-off probe span (~M*2*cap*beta).
    n_phases = 512
    up = jnp.ones((N, n_phases), bool).at[0, 1:].set(False)
    dyn = dict(
        drop=jnp.zeros((N, M), bool),
        up=up,
        speed=jnp.ones((N, n_phases)),
    )
    a = jnp.full((N,), 0.5)
    outs = simulator.simulate_stream(
        beta, d_up, d_ack, d_down, mode="ccp",
        cfg_static=(8.0 * R, 8.0, 1.0, 0.25),
        churn_static=(period, cap), dyn=dyn, a=a,
    )
    backoff = np.asarray(outs["backoff"])
    lost = np.asarray(outs["lost"])
    # helper 0 died after phase 0: all its packets sent after t=4 are lost
    assert lost[0].sum() > 0
    assert lost[1:].sum() == 0
    # exponential backoff: doubles per timeout, monotone once dead, capped
    b0 = backoff[0][lost[0]]
    assert b0.max() == cap
    assert (np.diff(b0) >= 0).all()
    ratios = b0[1:] / b0[:-1]
    assert set(np.unique(ratios)).issubset({1.0, 2.0})
    # survivors never back off
    assert (backoff[1:] == 1.0).all()
    # completion still certified from the survivors: ask for k results with
    # k far below what two healthy helpers produce over the horizon
    k = 40
    t, valid = simulator.completion_time(
        jnp.asarray(outs["tr"]), k, tx_end=jnp.asarray(outs["tx_end"])
    )
    assert bool(valid)
    assert np.isfinite(float(t))
    # and the dead helper's timeout probes are spaced at least as far apart
    # as the survivors' (backed-off TTI), never tighter
    tx0 = np.asarray(outs["tx"])[0]
    gaps = np.diff(tx0[np.asarray(lost[0])])
    assert gaps.min() > 0


def test_rejoining_helper_backoff_resets():
    """Down for phases 1-2, back up in phase 3+: after rejoin the first
    receipt resets the backoff to 1 and the helper contributes again."""
    N, M, period, cap = 2, 96, 3.0, 8.0
    beta = jnp.full((N, M), 0.5)
    d_up = jnp.full((N, M), 0.01)
    d_ack = jnp.full((N, M), 0.001)
    d_down = jnp.full((N, M), 0.01)
    n_phases = 16
    up = jnp.ones((N, n_phases), bool).at[0, 1:3].set(False)
    dyn = dict(drop=jnp.zeros((N, M), bool), up=up,
               speed=jnp.ones((N, n_phases)))
    outs = simulator.simulate_stream(
        beta, d_up, d_ack, d_down, mode="ccp",
        cfg_static=(8.0 * R, 8.0, 1.0, 0.25),
        churn_static=(period, cap), dyn=dyn, a=jnp.full((N,), 0.25),
    )
    lost0 = np.asarray(outs["lost"])[0]
    backoff0 = np.asarray(outs["backoff"])[0]
    assert lost0.sum() > 0, "helper 0 must have lost packets while down"
    last_lost = np.nonzero(lost0)[0].max()
    assert not lost0[last_lost + 1:].any(), "helper 0 must rejoin"
    assert backoff0[lost0].max() > 1.0, "timeouts must have backed off"
    # after the first post-rejoin receipt the backoff is 1 again
    assert (backoff0[last_lost + 1:] == 1.0).all()


def test_slowdown_phases_increase_completion_time():
    base = simulator.ScenarioConfig(
        N=20, scenario=1,
        churn=simulator.ChurnConfig(period=5.0, p_slow=0.0, slowdown=4.0),
    )
    slowed = simulator.ScenarioConfig(
        N=20, scenario=1,
        churn=simulator.ChurnConfig(period=5.0, p_slow=0.8, slowdown=4.0),
    )
    keys = simulator.batch_keys(4)
    t_base = simulator.run_batch(keys, base, R, "ccp")["T"].mean()
    t_slow = simulator.run_batch(keys, slowed, R, "ccp")["T"].mean()
    assert t_slow > 1.5 * t_base


def test_ccp_degrades_gracefully_vs_naive():
    """Small-scale fig_churn anchor: under loss-heavy churn on heterogeneous
    helpers, Naive's statically-provisioned ARQ timer costs it a far larger
    slowdown than CCP's adapted timeout."""
    cfg = simulator.ScenarioConfig(
        N=20, scenario=1, mu_choices=(1.0, 3.0, 9.0), a_mode="inv_mu",
        rate_lo=1e6, rate_hi=2e6,
        churn=simulator.ChurnConfig(period=10.0, p_down=0.05, p_slow=0.1,
                                    drop_prob=0.2, max_backoff=8.0),
    )
    keys = simulator.batch_keys(6)
    t_ccp = simulator.run_batch(keys, cfg, 300, "ccp")["T"].mean()
    t_best = simulator.run_batch(keys, cfg, 300, "best")["T"].mean()
    t_naive = simulator.run_batch(keys, cfg, 300, "naive")["T"].mean()
    assert t_ccp < t_naive, "CCP must beat Naive under churn"
    assert (t_ccp / t_best) < 0.6 * (t_naive / t_best), \
        "CCP's degradation vs Best must be far milder than Naive's"


# ---------------------------------------------------------------------------
# (c) zero-churn == static, bit-for-bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["ccp", "best", "naive"])
def test_neutral_churn_is_bit_for_bit_static(mode):
    static = CFG
    neutral = simulator.ScenarioConfig(
        N=20, scenario=1,
        churn=simulator.ChurnConfig(p_down=0.0, p_slow=0.0, drop_prob=0.0),
    )
    assert neutral.churn.neutral
    key = jax.random.PRNGKey(7)
    M = 128
    s = simulator._run_mode(key, static, R, mode, M_override=M)
    n = simulator._run_mode(key, neutral, R, mode, M_override=M)
    np.testing.assert_array_equal(np.float32(s["T"]), np.float32(n["T"]))
    np.testing.assert_array_equal(s["r_n"], n["r_n"])
    np.testing.assert_array_equal(s["efficiency"], n["efficiency"])
    assert (n["lost_frac"] == 0).all()
    assert (n["max_backoff"] == 1.0).all()
