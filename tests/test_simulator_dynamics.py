"""Dynamics engine tests: the vmapped batch runner and the churn model.

Covers the three contract points of the batched Monte-Carlo engine:
  (a) Engine.run over vmapped keys == per-key sequential Engine.run_one,
  (b) a helper that dies mid-task gets exponentially backed-off TTI
      (Alg. 1 line 13) and the task completes from the survivors,
  (c) a zero-churn ChurnConfig reproduces the static paper model
      bit-for-bit (the dynamics machinery is numerically invisible
      when its knobs are neutral).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine, policies, simulator

ENG = engine.Engine()

CFG = simulator.ScenarioConfig(N=20, scenario=1)
R = 400


def _stream(beta, d_up, d_ack, d_down, mode, cfg_static, **kw):
    """policy_stream under a registry name; returns the trace dict."""
    outs, _ = engine.policy_stream(
        beta, d_up, d_ack, d_down, policy=policies.get(mode),
        cfg_static=cfg_static, **kw)
    return outs


# ---------------------------------------------------------------------------
# (a) batch == sequential
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["ccp", "best", "naive"])
def test_engine_batch_matches_sequential(mode):
    reps = 4
    keys = simulator.batch_keys(reps)
    batch = ENG.run(CFG, mode, keys, R)
    for r in range(reps):
        seq = ENG.run_one(keys[r], CFG, mode, R, M_override=batch.M)
        np.testing.assert_allclose(batch["T"][r], seq["T"], rtol=1e-6)
        np.testing.assert_array_equal(batch["r_n"][r], seq["r_n"])
        np.testing.assert_allclose(
            batch["efficiency"][r], seq["efficiency"], rtol=1e-5
        )


def test_engine_batch_matches_sequential_under_churn():
    cfg = simulator.ScenarioConfig(
        N=20, scenario=1,
        churn=simulator.ChurnConfig(period=5.0, p_down=0.1, p_slow=0.2,
                                    drop_prob=0.05),
    )
    keys = simulator.batch_keys(3)
    batch = ENG.run(cfg, "ccp", keys, R)
    for r in range(3):
        seq = ENG.run_one(keys[r], cfg, "ccp", R, M_override=batch.M)
        np.testing.assert_allclose(batch["T"][r], seq["T"], rtol=1e-6)
        np.testing.assert_array_equal(batch["r_n"][r], seq["r_n"])


# ---------------------------------------------------------------------------
# key schedule
# ---------------------------------------------------------------------------

def test_batch_keys_fold_in_has_no_cross_seed_collisions():
    """The legacy ``PRNGKey(seed0*100003 + r)`` schedule collides across
    (seed0, rep) pairs — e.g. (0, 100003) == (1, 0); fold_in does not."""
    legacy_a = simulator.batch_keys(100004, seed0=0, schedule="legacy")
    legacy_b = simulator.batch_keys(1, seed0=1, schedule="legacy")
    np.testing.assert_array_equal(legacy_a[100003], legacy_b[0])  # the bug
    a = simulator.batch_keys(100004, seed0=0)
    b = simulator.batch_keys(1, seed0=1)
    assert not np.array_equal(np.asarray(a[100003]), np.asarray(b[0]))
    # and the default schedule is fold_in over the root key
    root = jax.random.PRNGKey(0)
    np.testing.assert_array_equal(a[17], jax.random.fold_in(root, 17))


def test_batch_keys_legacy_shim_matches_old_formula():
    old = jax.vmap(jax.random.PRNGKey)(5 * 100003 + jnp.arange(8))
    np.testing.assert_array_equal(
        simulator.batch_keys(8, seed0=5, schedule="legacy"), old
    )


# ---------------------------------------------------------------------------
# (b) mid-task death -> exponential backoff, completion from survivors
# ---------------------------------------------------------------------------

def test_dead_helper_backs_off_and_task_completes():
    """Helper 0 is up in phase 0 only, then down for good (period=4s).  Its
    TTI backoff must double per timeout up to the cap (Alg. 1 l.13) while the
    survivors keep streaming at backoff 1, and the (R+K)-th order statistic
    must still be reached from the survivors alone."""
    N, M, period, cap = 3, 64, 4.0, 8.0
    beta = jnp.full((N, M), 1.0)
    d_up = jnp.full((N, M), 0.01)
    d_ack = jnp.full((N, M), 0.001)
    d_down = jnp.full((N, M), 0.01)
    # The phase schedule wraps after n_phases*period seconds (rejoin is the
    # wrap's purpose — tested below); here the death must be final, so make
    # the wrap horizon far exceed the backed-off probe span (~M*2*cap*beta).
    n_phases = 512
    up = jnp.ones((N, n_phases), bool).at[0, 1:].set(False)
    dyn = dict(
        drop=jnp.zeros((N, M), bool),
        up=up,
        speed=jnp.ones((N, n_phases)),
    )
    a = jnp.full((N,), 0.5)
    outs = _stream(
        beta, d_up, d_ack, d_down, "ccp",
        cfg_static=(8.0 * R, 8.0, 1.0, 0.25),
        churn_static=(period, cap), dyn=dyn, a=a,
    )
    backoff = np.asarray(outs["backoff"])
    lost = np.asarray(outs["lost"])
    # helper 0 died after phase 0: all its packets sent after t=4 are lost
    assert lost[0].sum() > 0
    assert lost[1:].sum() == 0
    # exponential backoff: doubles per timeout, monotone once dead, capped
    b0 = backoff[0][lost[0]]
    assert b0.max() == cap
    assert (np.diff(b0) >= 0).all()
    ratios = b0[1:] / b0[:-1]
    assert set(np.unique(ratios)).issubset({1.0, 2.0})
    # survivors never back off
    assert (backoff[1:] == 1.0).all()
    # completion still certified from the survivors: ask for k results with
    # k far below what two healthy helpers produce over the horizon
    k = 40
    t, valid = simulator.completion_time(
        jnp.asarray(outs["tr"]), k, tx_end=jnp.asarray(outs["tx_end"])
    )
    assert bool(valid)
    assert np.isfinite(float(t))
    # and the dead helper's timeout probes are spaced at least as far apart
    # as the survivors' (backed-off TTI), never tighter
    tx0 = np.asarray(outs["tx"])[0]
    gaps = np.diff(tx0[np.asarray(lost[0])])
    assert gaps.min() > 0


def test_rejoining_helper_backoff_resets():
    """Down for phases 1-2, back up in phase 3+: after rejoin the first
    receipt resets the backoff to 1 and the helper contributes again."""
    N, M, period, cap = 2, 96, 3.0, 8.0
    beta = jnp.full((N, M), 0.5)
    d_up = jnp.full((N, M), 0.01)
    d_ack = jnp.full((N, M), 0.001)
    d_down = jnp.full((N, M), 0.01)
    n_phases = 16
    up = jnp.ones((N, n_phases), bool).at[0, 1:3].set(False)
    dyn = dict(drop=jnp.zeros((N, M), bool), up=up,
               speed=jnp.ones((N, n_phases)))
    outs = _stream(
        beta, d_up, d_ack, d_down, "ccp",
        cfg_static=(8.0 * R, 8.0, 1.0, 0.25),
        churn_static=(period, cap), dyn=dyn, a=jnp.full((N,), 0.25),
    )
    lost0 = np.asarray(outs["lost"])[0]
    backoff0 = np.asarray(outs["backoff"])[0]
    assert lost0.sum() > 0, "helper 0 must have lost packets while down"
    last_lost = np.nonzero(lost0)[0].max()
    assert not lost0[last_lost + 1:].any(), "helper 0 must rejoin"
    assert backoff0[lost0].max() > 1.0, "timeouts must have backed off"
    # after the first post-rejoin receipt the backoff is 1 again
    assert (backoff0[last_lost + 1:] == 1.0).all()


def test_slowdown_phases_increase_completion_time():
    base = simulator.ScenarioConfig(
        N=20, scenario=1,
        churn=simulator.ChurnConfig(period=5.0, p_slow=0.0, slowdown=4.0),
    )
    slowed = simulator.ScenarioConfig(
        N=20, scenario=1,
        churn=simulator.ChurnConfig(period=5.0, p_slow=0.8, slowdown=4.0),
    )
    keys = simulator.batch_keys(4)
    t_base = ENG.run(base, "ccp", keys, R)["T"].mean()
    t_slow = ENG.run(slowed, "ccp", keys, R)["T"].mean()
    assert t_slow > 1.5 * t_base


def test_ccp_degrades_gracefully_vs_naive():
    """Small-scale fig_churn anchor: under loss-heavy churn on heterogeneous
    helpers, Naive's statically-provisioned ARQ timer costs it a far larger
    slowdown than CCP's adapted timeout."""
    cfg = simulator.ScenarioConfig(
        N=20, scenario=1, mu_choices=(1.0, 3.0, 9.0), a_mode="inv_mu",
        rate_lo=1e6, rate_hi=2e6,
        churn=simulator.ChurnConfig(period=10.0, p_down=0.05, p_slow=0.1,
                                    drop_prob=0.2, max_backoff=8.0),
    )
    keys = simulator.batch_keys(6)
    t_ccp = ENG.run(cfg, "ccp", keys, 300)["T"].mean()
    t_best = ENG.run(cfg, "best", keys, 300)["T"].mean()
    t_naive = ENG.run(cfg, "naive", keys, 300)["T"].mean()
    assert t_ccp < t_naive, "CCP must beat Naive under churn"
    assert (t_ccp / t_best) < 0.6 * (t_naive / t_best), \
        "CCP's degradation vs Best must be far milder than Naive's"


# ---------------------------------------------------------------------------
# (c) zero-churn == static, bit-for-bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["ccp", "best", "naive"])
@pytest.mark.parametrize("outage_dist", ["phase", "geometric", "lognormal"])
def test_neutral_churn_is_bit_for_bit_static(mode, outage_dist):
    """A ChurnConfig with every loss knob at zero — whatever the structural
    knobs (outage-duration law, GE recovery prob, cell fraction) — must be
    numerically invisible."""
    static = CFG
    neutral = simulator.ScenarioConfig(
        N=20, scenario=1,
        churn=simulator.ChurnConfig(
            p_down=0.0, p_slow=0.0, drop_prob=0.0,
            outage_dist=outage_dist, ge_p_bad=0.0, p_cell=0.0,
        ),
    )
    assert neutral.churn.neutral
    key = jax.random.PRNGKey(7)
    M = 128
    s = ENG.run_one(key, static, mode, R, M_override=M)
    n = ENG.run_one(key, neutral, mode, R, M_override=M)
    np.testing.assert_array_equal(np.float32(s["T"]), np.float32(n["T"]))
    np.testing.assert_array_equal(s["r_n"], n["r_n"])
    np.testing.assert_array_equal(s["efficiency"], n["efficiency"])
    assert (n["lost_frac"] == 0).all()
    assert (n["max_backoff"] == 1.0).all()


# ---------------------------------------------------------------------------
# (d) Gilbert–Elliott burst loss
# ---------------------------------------------------------------------------

def test_ge_stationary_loss_rate():
    """The GE chain starts in its stationary distribution, so the marginal
    per-packet loss rate over many helpers/packets must match
    ``pi_bad*ge_loss_bad + (1-pi_bad)*ge_loss_good``."""
    ch = simulator.ChurnConfig(ge_p_bad=0.05, ge_p_good=0.2,
                               ge_loss_bad=0.8, ge_loss_good=0.02)
    cfg = simulator.ScenarioConfig(N=100, scenario=1, churn=ch)
    out = ENG.run(cfg, "ccp", simulator.batch_keys(3), 400)
    measured = float(out["lost_frac"].mean())
    expected = ch.ge_loss_rate
    assert abs(measured - expected) < 0.15 * expected, (measured, expected)


def test_ge_losses_are_bursty():
    """With a slow-recovering bad state (small ge_p_good) and
    loss_bad=1/loss_good=0, losses are runs of mean length ~1/ge_p_good —
    far longer than i.i.d. loss at the same marginal rate would produce."""
    ch = simulator.ChurnConfig(ge_p_bad=0.02, ge_p_good=0.1,
                               ge_loss_bad=1.0, ge_loss_good=0.0)
    cfg = simulator.ScenarioConfig(N=100, scenario=1, churn=ch)
    # the engine only reports per-helper lost_frac; run the stream directly
    # to get the raw (N, M) loss table for run-length statistics.
    k = jax.random.PRNGKey(0)
    k_h, k_p = jax.random.split(k)
    mu, a, rate = simulator.draw_helpers(k_h, cfg)
    beta, d_up, d_ack, d_down = simulator.draw_packet_tables(
        k_p, cfg, mu, a, rate, 256, 400)
    dyn = simulator.draw_dynamics(jax.random.fold_in(k, 0xC0DE), cfg, 256)
    outs = _stream(
        beta, d_up, d_ack, d_down, "best",
        cfg_static=(8.0 * 400, 8.0, 1.0, 0.25),
        churn_static=cfg.churn.static_key(), dyn=dyn, a=a,
    )
    table = np.asarray(outs["lost"])
    run_lengths = []
    for row in table:
        n = 0
        for v in row:
            if v:
                n += 1
            elif n:
                run_lengths.append(n)
                n = 0
        if n:
            run_lengths.append(n)
    mean_run = np.mean(run_lengths)
    # i.i.d. loss at this marginal rate would give mean run ~1/(1-rate)≈1.2;
    # the GE chain gives ~1/ge_p_good = 10.
    assert mean_run > 3.0, mean_run
    assert abs(mean_run - 1.0 / cfg.churn.ge_p_good) < 0.5 / cfg.churn.ge_p_good


# ---------------------------------------------------------------------------
# (e) correlated cell outages + duration distributions
# ---------------------------------------------------------------------------

def test_cell_outage_takes_members_down_simultaneously():
    """Hand-built single cell event [2, 4): member helpers lose exactly the
    packets arriving in the window, non-members lose nothing."""
    N, M, period = 3, 64, 5.0
    beta = jnp.full((N, M), 0.25)
    d_up = jnp.full((N, M), 0.01)
    d_ack = jnp.full((N, M), 0.001)
    d_down = jnp.full((N, M), 0.01)
    P = 8  # window = 40s >> horizon, so no wrap in this test
    dyn = dict(
        drop=jnp.zeros((N, M), bool),
        speed=jnp.ones((N, P)),
        up=jnp.ones((N, P), bool),
        cell_start=jnp.asarray([2.0]),
        cell_end=jnp.asarray([4.0]),
        cell_mask=jnp.asarray([[True], [True], [False]]),
    )
    outs = _stream(
        beta, d_up, d_ack, d_down, "best",
        cfg_static=(8.0 * R, 8.0, 1.0, 0.25),
        churn_static=(period, 8.0, "phase", False, True),
        dyn=dyn, a=jnp.full((N,), 0.1),
    )
    lost = np.asarray(outs["lost"])
    arrive = np.asarray(outs["arrive"])
    in_win = (arrive >= 2.0) & (arrive < 4.0)
    assert lost[2].sum() == 0, "non-member must not lose packets"
    assert lost[0].sum() > 0 and lost[1].sum() > 0
    # members lose exactly the packets whose arrival (or compute start,
    # which for back-to-back streaming can trail into the window) hits it
    assert (lost[:2] & in_win[:2] == in_win[:2]).all()


def test_outage_duration_distributions():
    """Geometric durations are whole periods with the configured mean;
    log-normal durations are continuous with the configured mean."""
    key = jax.random.PRNGKey(0)
    for dist, check in (
        ("geometric", lambda d: np.allclose(d % 5.0, 0.0)),
        ("lognormal", lambda d: not np.allclose(d % 5.0, 0.0)),
    ):
        ch = simulator.ChurnConfig(period=5.0, outage_dist=dist,
                                   outage_mean=15.0, outage_sigma=0.5,
                                   p_down=1.0)
        d = np.asarray(simulator._draw_durations(key, ch, (4000,)))
        assert (d > 0).all()
        assert check(d), dist
        assert abs(d.mean() - 15.0) < 2.0, (dist, d.mean())


def test_duration_outages_last_longer_than_phase_outages():
    """With the same outage start rate, geometric durations with mean >>
    period must produce more downtime (higher loss) than whole-phase
    outages."""
    base = dict(period=5.0, p_down=0.1, max_backoff=8.0)
    keys = simulator.batch_keys(4)
    cfg_p = simulator.ScenarioConfig(
        N=30, scenario=1, churn=simulator.ChurnConfig(**base))
    cfg_g = simulator.ScenarioConfig(
        N=30, scenario=1, churn=simulator.ChurnConfig(
            outage_dist="geometric", outage_mean=20.0, **base))
    lost_p = ENG.run(cfg_p, "ccp", keys, 300)["lost_frac"].mean()
    lost_g = ENG.run(cfg_g, "ccp", keys, 300)["lost_frac"].mean()
    assert lost_g > 1.5 * lost_p, (lost_p, lost_g)


# ---------------------------------------------------------------------------
# (f) naive + oracle timer baseline
# ---------------------------------------------------------------------------

def test_naive_oracle_timer_between_naive_and_best():
    """The oracle-timer Naive removes the timer-adaptation penalty but keeps
    the stop-and-wait pipelining penalty: under loss-heavy churn it must
    beat static-timer Naive and stay above Best."""
    cfg = simulator.ScenarioConfig(
        N=20, scenario=1, mu_choices=(1.0, 3.0, 9.0), a_mode="inv_mu",
        rate_lo=1e6, rate_hi=2e6,
        churn=simulator.ChurnConfig(period=10.0, p_down=0.05, p_slow=0.1,
                                    drop_prob=0.2, max_backoff=8.0),
    )
    keys = simulator.batch_keys(6)
    t = {m: ENG.run(cfg, m, keys, 300)["T"].mean()
         for m in ("best", "naive", "naive_oracle")}
    assert t["naive_oracle"] < t["naive"], t
    assert t["naive_oracle"] > t["best"], t


# ---------------------------------------------------------------------------
# (g) device-sharded batch == unsharded vmap, bitwise
# ---------------------------------------------------------------------------

_SHARD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + \
    " --xla_force_host_platform_device_count=8"
import json
import jax
import numpy as np
from repro.core import engine, simulator

assert len(jax.local_devices()) == 8
cfg = simulator.ScenarioConfig(
    N=8, scenario=1,
    churn=simulator.ChurnConfig(p_down=0.05, drop_prob=0.1,
                                ge_p_bad=0.02, ge_p_good=0.2,
                                ge_loss_bad=0.5),
)

def eq(x, y):
    x, y = np.asarray(x), np.asarray(y)
    # bitwise equality; efficiency carries NaN for helpers that computed
    # nothing within T, and NaN == NaN must count as equal here
    if x.dtype.kind == "f":
        return np.array_equal(x, y, equal_nan=True)
    return np.array_equal(x, y)

out = {}
# 11 reps: not a device-count multiple, so the pad-and-slice path runs too.
keys = simulator.batch_keys(11)
for mode in ("ccp", "naive_oracle", "rateless_ccp"):
    a = engine.Engine().run(cfg, mode, keys, 120)
    b = engine.Engine(shard=True).run(cfg, mode, keys, 120)
    out[f"{mode}_bitwise_equal"] = bool(
        all(eq(a[k], b[k]) for k in a.keys()))
    out[f"{mode}_M"] = int(a["M"])
# explicit device subset (3 of 8, another pad case)
c = engine.Engine(shard=True, devices=jax.local_devices()[:3]).run(
    cfg, "ccp", keys, 120)
a = engine.Engine().run(cfg, "ccp", keys, 120)
out["subset_bitwise_equal"] = bool(all(eq(a[k], c[k]) for k in a.keys()))
print("RESULT:" + json.dumps(out))
"""


@pytest.mark.multidevice
def test_sharded_engine_matches_vmap_bitwise():
    """Engine(shard=True) over 8 forced host devices returns results
    bitwise identical to the unsharded vmap — including the decoder-in-the-
    loop rateless policy (its scan-carried DecoderState and binary-search
    finalize must shard transparently), when the batch does not divide the
    device count (padding), and on a device subset."""
    import os
    import subprocess
    import sys

    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run(
        [sys.executable, "-c", _SHARD_SCRIPT], capture_output=True,
        text=True, timeout=900,
        env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    import json
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT:")][0]
    out = json.loads(line[len("RESULT:"):])
    assert out["ccp_bitwise_equal"], out
    assert out["naive_oracle_bitwise_equal"], out
    assert out["rateless_ccp_bitwise_equal"], out
    assert out["subset_bitwise_equal"], out
