"""End-to-end tests of the paper's object: distributed coded matmul with
shard losses + recovery (single-device path; the mesh path is exercised in
tests/test_multihost_subprocess.py on 8 host devices)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import coded_matmul as cm


def test_plan_uniform_placement():
    plan = cm.plan_coded_matmul(rows=1024, n_shards=8, overhead=0.25, bm=128)
    assert plan.code.R == 8
    assert plan.placement.shape[0] == 8
    # uniform blocks per shard, disjoint coverage of the coded space
    flat = np.sort(plan.placement.reshape(-1))
    np.testing.assert_array_equal(flat, np.arange(plan.code.n_coded))


def test_run_and_recover_no_loss():
    plan = cm.plan_coded_matmul(rows=64, n_shards=4, overhead=0.5, bm=8)
    a = jax.random.normal(jax.random.PRNGKey(0), (64, 32))
    x = jax.random.normal(jax.random.PRNGKey(1), (32, 16))
    out = cm.run(plan, a, x)
    y = cm.recover(plan, out, survivors=np.arange(4))
    np.testing.assert_allclose(np.asarray(y), np.asarray(a @ x), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("lost_shard", [0, 1, 3])
def test_recover_with_lost_shard(lost_shard):
    """The paper's headline property: task completes with any shard down."""
    plan = cm.plan_coded_matmul(rows=64, n_shards=4, overhead=0.6, bm=8, seed=2)
    a = jax.random.normal(jax.random.PRNGKey(2), (64, 32))
    x = jax.random.normal(jax.random.PRNGKey(3), (32, 8))
    out = cm.run(plan, a, x)
    survivors = np.setdiff1d(np.arange(4), [lost_shard])
    y = cm.recover(plan, out, survivors)
    np.testing.assert_allclose(np.asarray(y), np.asarray(a @ x), rtol=2e-3, atol=2e-3)


def test_round_robin_spreads_systematic_blocks():
    """Losing one shard must not lose a contiguous run of source blocks."""
    plan = cm.plan_coded_matmul(rows=1024, n_shards=8, overhead=0.25, bm=128)
    sys_blocks_lost = [b for b in plan.placement[0] if b < plan.code.R]
    diffs = np.diff(sys_blocks_lost)
    assert np.all(diffs >= plan.n_shards)


def test_pallas_kernel_path_matches():
    plan = cm.plan_coded_matmul(rows=64, n_shards=4, overhead=0.5, bm=8, seed=1)
    a = jax.random.normal(jax.random.PRNGKey(4), (64, 32))
    x = jax.random.normal(jax.random.PRNGKey(5), (32, 16))
    out_ref = cm.run(plan, a, x, use_pallas=False)
    out_k = cm.run(plan, a, x, use_pallas=True, interpret=True)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_ref),
                               rtol=2e-4, atol=2e-4)
