"""Paper §6 Efficiency table: measured vs. theoretical (eq. 12) helper
efficiency at R=8000 (mu ~ U{1,3,9}, a=1/mu).

Anchors: measured ~99.7% (Sc.1) / ~99.9% (Sc.2); theory ~99.4%;
measured >= theory (theory is the average-analysis lower curve).
"""

from __future__ import annotations

import numpy as np

from repro.configs.ccp_paper import EFFICIENCY, FIG4
from repro.core import engine, simulator, theory

from .common import certified, emit, policy_meta


def run(reps: int = 20, R: int = 8000, shard: bool = False,
        policy: str = "ccp") -> dict:
    rows = []
    eng = engine.Engine(shard=shard)
    keys = simulator.batch_keys(reps)
    for sc in (1, 2):
        cfg = FIG4[sc]
        out = eng.run(cfg, policy, keys, R)
        valid = certified(out, "efficiency")
        eff = float(np.nanmean(out["efficiency"][valid]))
        rtt = (8.0 * R + 8.0) / out["rate"][valid]
        theo = float(np.mean(theory.efficiency(
            rtt.reshape(-1), out["a"][valid].reshape(-1),
            out["mu"][valid].reshape(-1))))
        rows.append({
            "scenario": sc,
            "measured": eff,
            "theory_eq12": theo,
            "invalid": int((~valid).sum()),
        })
    emit("efficiency", rows,
         derived=";".join(
             f"sc{r['scenario']}_meas={r['measured']:.4f},theory={r['theory_eq12']:.4f}"
             for r in rows),
         policies=policy_meta((policy,)))
    return {"rows": rows}


if __name__ == "__main__":
    for r in run()["rows"]:
        print(f"  scenario {r['scenario']}: measured {r['measured']:.4%} "
              f"vs theory {r['theory_eq12']:.4%}")
