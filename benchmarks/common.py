"""Shared helpers for the paper-figure benchmarks."""

from __future__ import annotations

import json
import os
import pathlib
import time
from typing import Callable, Dict, List

import jax
import numpy as np

# BENCH_OUT_DIR overrides the artifact directory (the smoke-test lane points
# it at a tmpdir so tiny-scale runs never clobber the committed artifacts).
OUT_DIR = pathlib.Path(
    os.environ.get(
        "BENCH_OUT_DIR",
        pathlib.Path(__file__).resolve().parent.parent / "experiments" / "bench",
    )
)


def _stats(a: np.ndarray) -> Dict[str, float]:
    return {"mean": float(a.mean()), "std": float(a.std()),
            "sem": float(a.std() / np.sqrt(len(a)))}


def mc(fn: Callable, cfg, R: int, reps: int, seed0: int = 0) -> Dict[str, float]:
    """Sequential Monte-Carlo mean/std of fn(key, cfg, R)["T"] over ``reps``
    draws.  Kept for the numpy-driven baseline reference paths; the figure
    benchmarks go through the vmapped :func:`mc_policy` instead.  Keys come
    from the same fold_in schedule, so baseline and policy rows in one
    figure share helper draws rep-for-rep."""
    from repro.core import simulator

    keys = simulator.batch_keys(reps, seed0)
    ts = []
    for r in range(reps):
        ts.append(fn(keys[r], cfg, R)["T"])
    return _stats(np.asarray(ts))


def certified(out: Dict, label: str) -> np.ndarray:
    """The certification mask of an ``Engine.run`` result, as the one shared
    drop-the-invalid-reps gate: raises when *no* rep is certified (horizon
    cap hit for the whole batch), otherwise returns the boolean mask the
    caller must apply before aggregating (counting ``~mask`` as invalid)."""
    valid = np.asarray(out["valid"])
    if not valid.any():
        raise RuntimeError(
            f"{label}: no certified rep at horizon cap (M={out['M']}) — "
            "churn config too hostile?"
        )
    return valid


def mc_policy(cfg, R: int, reps: int, policy: str, seed0: int = 0,
              shard: bool = False) -> Dict[str, float]:
    """Batched Monte-Carlo over ``reps`` vmapped keys via the policy engine
    (one compile + one device call instead of ``reps`` sequential runs);
    ``policy`` is any registered name — ``ccp``, ``best``, ``naive``,
    ``naive_oracle``, ``uncoded_mean``/``uncoded_mu``, ``hcmm``,
    ``adaptive_rate``, ... Uncertified reps (horizon cap hit under heavy
    churn -> T possibly inf or understated) are excluded from the stats and
    counted in ``invalid``.  ``shard=True`` splits the key batch over the
    local devices."""
    from repro.core import engine, simulator

    out = engine.Engine(shard=shard).run(
        cfg, policy, simulator.batch_keys(reps, seed0), R)
    valid = certified(out, f"mc_policy policy={policy!r} R={R}")
    stats = _stats(np.asarray(out["T"])[valid])
    stats["invalid"] = int((~valid).sum())
    return stats


def policy_meta(names) -> Dict[str, int]:
    """``meta.policy`` entry for bench artifacts: registry name -> version
    for every policy the run swept (artifact rows from different policy
    implementations are never compared silently)."""
    from repro.core import policies

    return {n: policies.get(n).version for n in names}


def emit(name: str, rows: List[dict], derived: str = "",
         policies: Dict[str, int] | None = None,
         extra_meta: Dict[str, object] | None = None) -> None:
    """Write JSON artifact + the harness CSV line ``name,us_per_call,derived``.

    The artifact is ``{"meta": {...}, "data": rows}``: ``meta`` records the
    PRNG key schedule (PR 2 switched batch_keys from the collision-prone
    ``seed0*100003 + r`` arithmetic to ``fold_in``) and — for policy sweeps
    — ``meta.policy``, the registry name -> version map from
    :func:`policy_meta`, plus ``meta.decoder``, marking per policy whether
    its completion rule actually *decodes* in the loop (``"in_loop"``) or
    counts packets (``"counter"``), so delay trajectories from the two
    completion semantics are never compared silently.  ``extra_meta``
    merges figure-specific keys (e.g. fig_fleet's ``discipline``)."""
    from repro.core import policies as policy_registry
    from repro.core import simulator

    OUT_DIR.mkdir(parents=True, exist_ok=True)
    meta = {"key_schedule": simulator.KEY_SCHEDULE}
    if policies:
        meta["policy"] = dict(policies)
        meta["decoder"] = {
            n: ("in_loop" if policy_registry.get(n).uses_decoder
                else "counter")
            for n in policies
        }
    if extra_meta:
        meta.update(extra_meta)
    doc = {"meta": meta, "data": rows}
    (OUT_DIR / f"{name}.json").write_text(json.dumps(doc, indent=1))
    print(f"{name},-,{derived}")


def timed(fn: Callable, *args, warmup: int = 1, iters: int = 3):
    for _ in range(warmup):
        r = fn(*args)
    jax.block_until_ready(r) if hasattr(r, "block_until_ready") else None
    t0 = time.perf_counter()
    for _ in range(iters):
        r = fn(*args)
    try:
        jax.block_until_ready(r)
    except Exception:
        pass
    return (time.perf_counter() - t0) / iters * 1e6, r  # us per call
