"""Shared helpers for the paper-figure benchmarks."""

from __future__ import annotations

import json
import pathlib
import time
from typing import Callable, Dict, List

import jax
import numpy as np

OUT_DIR = pathlib.Path(__file__).resolve().parent.parent / "experiments" / "bench"


def _stats(a: np.ndarray) -> Dict[str, float]:
    return {"mean": float(a.mean()), "std": float(a.std()),
            "sem": float(a.std() / np.sqrt(len(a)))}


def mc(fn: Callable, cfg, R: int, reps: int, seed0: int = 0) -> Dict[str, float]:
    """Sequential Monte-Carlo mean/std of fn(key, cfg, R)["T"] over ``reps``
    draws.  Used for the numpy-driven baselines (uncoded/HCMM); the simulator
    modes go through the vmapped :func:`mc_sim` instead."""
    ts = []
    for r in range(reps):
        ts.append(fn(jax.random.PRNGKey(seed0 * 100003 + r), cfg, R)["T"])
    return _stats(np.asarray(ts))


def mc_sim(cfg, R: int, reps: int, mode: str, seed0: int = 0) -> Dict[str, float]:
    """Batched Monte-Carlo over ``reps`` vmapped keys via simulator.run_batch
    (one compile + one device call instead of ``reps`` sequential runs).
    Uncertified reps (horizon cap hit under heavy churn -> T possibly inf or
    understated) are excluded from the stats and counted in ``invalid``."""
    from repro.core import simulator

    out = simulator.run_batch(simulator.batch_keys(reps, seed0), cfg, R, mode)
    t, valid = np.asarray(out["T"]), np.asarray(out["valid"])
    if not valid.any():
        raise RuntimeError(
            f"mc_sim: no certified rep at horizon cap (M={out['M']}) for "
            f"mode={mode!r}, R={R} — churn config too hostile?"
        )
    stats = _stats(t[valid])
    stats["invalid"] = int((~valid).sum())
    return stats


def emit(name: str, rows: List[dict], derived: str = "") -> None:
    """Write JSON artifact + the harness CSV line ``name,us_per_call,derived``."""
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    (OUT_DIR / f"{name}.json").write_text(json.dumps(rows, indent=1))
    print(f"{name},-,{derived}")


def timed(fn: Callable, *args, warmup: int = 1, iters: int = 3):
    for _ in range(warmup):
        r = fn(*args)
    jax.block_until_ready(r) if hasattr(r, "block_until_ready") else None
    t0 = time.perf_counter()
    for _ in range(iters):
        r = fn(*args)
    try:
        jax.block_until_ready(r)
    except Exception:
        pass
    return (time.perf_counter() - t0) / iters * 1e6, r  # us per call
