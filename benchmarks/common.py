"""Shared helpers for the paper-figure benchmarks."""

from __future__ import annotations

import json
import pathlib
import time
from typing import Callable, Dict, List

import jax
import numpy as np

OUT_DIR = pathlib.Path(__file__).resolve().parent.parent / "experiments" / "bench"


def mc(fn: Callable, cfg, R: int, reps: int, seed0: int = 0) -> Dict[str, float]:
    """Monte-Carlo mean/std of fn(key, cfg, R)["T"] over ``reps`` draws."""
    ts = []
    for r in range(reps):
        ts.append(fn(jax.random.PRNGKey(seed0 * 100003 + r), cfg, R)["T"])
    a = np.asarray(ts)
    return {"mean": float(a.mean()), "std": float(a.std()),
            "sem": float(a.std() / np.sqrt(len(a)))}


def emit(name: str, rows: List[dict], derived: str = "") -> None:
    """Write JSON artifact + the harness CSV line ``name,us_per_call,derived``."""
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    (OUT_DIR / f"{name}.json").write_text(json.dumps(rows, indent=1))
    print(f"{name},-,{derived}")


def timed(fn: Callable, *args, warmup: int = 1, iters: int = 3):
    for _ in range(warmup):
        r = fn(*args)
    jax.block_until_ready(r) if hasattr(r, "block_until_ready") else None
    t0 = time.perf_counter()
    for _ in range(iters):
        r = fn(*args)
    try:
        jax.block_until_ready(r)
    except Exception:
        pass
    return (time.perf_counter() - t0) / iters * 1e6, r  # us per call
