"""Render the dry-run + bench JSON artifacts into EXPERIMENTS.md sections
(markdown tables). Run after the sweep + benchmarks:

  PYTHONPATH=src python -m benchmarks.report_md > experiments/report.md
"""

from __future__ import annotations

import json
import pathlib

ROOT = pathlib.Path(__file__).resolve().parent.parent / "experiments"


def _load(d):
    out = []
    for p in sorted((ROOT / d).glob("*.json")):
        out.append(json.loads(p.read_text()))
    return out


def fmt_s(x):
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    return f"{x*1e3:.1f}ms"


def dryrun_tables():
    cells = _load("dryrun")
    print("### Dry-run summary\n")
    ok = [c for c in cells if c["status"] == "ok"]
    skip = [c for c in cells if c["status"] == "skip"]
    fail = [c for c in cells if c["status"] == "fail"]
    print(f"- cells: {len(cells)} total = {len(ok)} compiled ok, "
          f"{len(skip)} skipped (long_500k on full-attention archs), "
          f"{len(fail)} failed\n")
    if fail:
        for c in fail:
            print(f"  - FAIL {c['arch']} {c['shape']} {c['mesh']}: {c['error']}")
        print()

    print("### Per-device memory (single-pod cells)\n")
    print("| arch | shape | params/dev | args/dev | temp/dev | cache/dev |")
    print("|---|---|---|---|---|---|")
    for c in ok:
        if c["mesh"] != "single":
            continue
        m = c.get("memory", {})
        gb = lambda k: (f"{m[k]/1e9:.2f} GB" if k in m else "-")
        print(f"| {c['arch']} | {c['shape']} | "
              f"{gb('param_bytes_per_device_est')} | "
              f"{gb('argument_size_in_bytes')} | {gb('temp_size_in_bytes')} | "
              f"{gb('cache_bytes_per_device_est')} |")
    print()

    for mesh in ("single", "multi"):
        print(f"### Roofline table — {mesh} pod "
              f"({'256' if mesh == 'single' else '512'} chips)\n")
        print("| arch | shape | compute | memory | collective | dominant |"
              " useful flops | roofline frac |")
        print("|---|---|---|---|---|---|---|---|")
        for c in cells:
            if c["mesh"] != mesh:
                continue
            if c["status"] == "skip":
                print(f"| {c['arch']} | {c['shape']} | SKIP | | | "
                      f"{c['skip_reason'][:40]}… | | |")
                continue
            if c["status"] != "ok":
                continue
            r = c["roofline"]
            ur = r.get("useful_flops_ratio")
            rf_ = r.get("roofline_fraction")
            ur_s = f"{ur:.2f}" if ur is not None else "-"
            rf_s = f"{rf_*100:.2f}%" if rf_ is not None else "-"
            print(f"| {c['arch']} | {c['shape']} | {fmt_s(r['compute_s'])} | "
                  f"{fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} | "
                  f"{r['dominant']} | {ur_s} | {rf_s} |")
        print()


def perf_tables():
    runs = _load("perf")
    if not runs:
        return
    print("### Perf iterations (raw artifacts)\n")
    print("| cell | opts | compute | memory | collective | dominant |")
    print("|---|---|---|---|---|---|")
    for c in runs:
        if c.get("status") != "ok":
            continue
        r = c["roofline"]
        print(f"| {c['arch']}/{c['shape']}/{c['mesh']} | {c.get('opts')} | "
              f"{fmt_s(r['compute_s'])} | {fmt_s(r['memory_s'])} | "
              f"{fmt_s(r['collective_s'])} | {r['dominant']} |")
    print()


if __name__ == "__main__":
    dryrun_tables()
    perf_tables()
