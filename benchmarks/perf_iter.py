"""§Perf hillclimb driver: re-lower a dry-run cell with knob overrides and
diff the roofline terms against the paper-faithful baseline.

Usage (one iteration):
  PYTHONPATH=src python -m benchmarks.perf_iter --arch qwen3-moe-235b-a22b \
      --shape train_4k --set remat_policy=dots --tag it1

Results land in experiments/perf/<arch>__<shape>__<tag>.json with the
baseline deltas precomputed; EXPERIMENTS.md §Perf records the
hypothesis → change → before → after → verdict chain.
"""

import argparse
import json
import pathlib

PERF_DIR = pathlib.Path(__file__).resolve().parent.parent / "experiments" / "perf"
DRYRUN_DIR = pathlib.Path(__file__).resolve().parent.parent / "experiments" / "dryrun"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--set", action="append", default=[],
                    help="knob=value (remat_policy=dots, n_micro=4, ...)")
    ap.add_argument("--tag", required=True)
    args = ap.parse_args()

    opts = {}
    for kv in args.set:
        k, v = kv.split("=")
        opts[k] = int(v) if v.isdigit() else v
    # reset module-level knobs after the run so the process stays clean
    from repro.models.moe import set_moe_opts

    from repro.launch.dryrun import run_cell

    res = run_cell(args.arch, args.shape, args.mesh == "multi", opts=opts)
    res["opts"] = opts
    base_p = DRYRUN_DIR / f"{args.arch}__{args.shape}__{args.mesh}.json"
    if base_p.exists():
        base = json.loads(base_p.read_text())
        if base.get("status") == "ok" and res.get("status") == "ok":
            b, n = base["roofline"], res["roofline"]
            res["delta_vs_baseline"] = {
                k: {"before": b[k], "after": n[k],
                    "change": (n[k] - b[k]) / b[k] if b[k] else None}
                for k in ("compute_s", "memory_s", "collective_s")
            }
            res["baseline_dominant"] = b["dominant"]
    PERF_DIR.mkdir(parents=True, exist_ok=True)
    out = PERF_DIR / f"{args.arch}__{args.shape}__{args.tag}.json"
    out.write_text(json.dumps(res, indent=1, default=str))
    if "delta_vs_baseline" in res:
        for k, d in res["delta_vs_baseline"].items():
            print(f"{k}: {d['before']:.4f}s -> {d['after']:.4f}s "
                  f"({d['change']:+.1%})")
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
