"""Beyond-paper figure: the price of delayed feedback.

The paper's Algorithm 1 paces on instantly-observed receipts; PR 8's
transport layer (docs/transport.md) makes the feedback channel physical —
each ACK rides a per-helper RTT process and can itself be lost (one NACK
retransmission round).  This figure sweeps the mean feedback RTT across
three churn/RTT regimes:

  iid    — i.i.d. packet drops, *fixed* return-path RTT (provisioned link)
  burst  — Gilbert–Elliott burst fades, *lognormal* RTT jitter (WiFi)
  cell   — correlated cell outages, *cell-spike* RTT (bufferbloat: the
           return path occasionally inflates 10x)

and reports completion delay and efficiency per policy.  The story:

  * ``best`` is open-loop (oracle TTI pacing reads no feedback) — its
    curve is *flat* by construction, the control for the experiment;
  * ``ccp`` pays for late observations twice: pacing stalls on delayed
    receipts, and every loss is detected one (or two) RTTs late;
  * ``tfrc_ccp`` answers a fade with *one* congestion signal (the RFC
    5348 loss-event rate) instead of a per-lost-packet backoff cascade,
    so at the high-RTT end of the burst sweep its completion delay
    degrades no worse than ``ccp``'s (the smoke anchor pinned by
    tests/test_bench_smoke.py), at a small efficiency cost from pacing
    through fades it cannot observe yet.

Uncertified reps are dropped and counted, never averaged.  The artifact
carries ``meta.rtt`` provenance: the swept means and each regime's RTT
distribution parameters.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import engine, simulator

from .common import _stats, certified, emit, policy_meta

N = 50
R = 1000
MU_CHOICES = (1.0, 3.0, 9.0)
POLICIES = ("ccp", "tfrc_ccp", "best")

RTT_SWEEP = (0.0, 0.25, 1.0, 4.0)


def _base(churn: simulator.ChurnConfig, n: int = N) -> simulator.ScenarioConfig:
    return simulator.ScenarioConfig(
        N=n, scenario=1, mu_choices=MU_CHOICES, a_mode="inv_mu",
        rate_lo=1e6, rate_hi=2e6, churn=churn,
    )


def iid_cfg(rtt_mean: float, n: int = N) -> simulator.ScenarioConfig:
    return _base(simulator.ChurnConfig(
        period=10.0, drop_prob=0.1, max_backoff=8.0,
        rtt_dist="fixed", rtt_mean=rtt_mean, rtt_het=0.5), n)


def burst_cfg(rtt_mean: float, n: int = N) -> simulator.ScenarioConfig:
    # fig_churn's burst regime (stationary loss ~17%) under jittered RTT.
    return _base(simulator.ChurnConfig(
        period=10.0, max_backoff=8.0,
        ge_p_bad=0.06, ge_p_good=0.25, ge_loss_good=0.0, ge_loss_bad=0.9,
        rtt_dist="lognormal", rtt_mean=rtt_mean, rtt_sigma=0.5), n)


def cell_cfg(rtt_mean: float, n: int = N) -> simulator.ScenarioConfig:
    return _base(simulator.ChurnConfig(
        period=5.0, max_backoff=8.0, drop_prob=0.05,
        p_cell=0.25, cell_frac=0.6,
        outage_dist="lognormal", outage_mean=4.0, outage_sigma=0.5,
        rtt_dist="cell", rtt_mean=rtt_mean,
        rtt_spike_prob=0.05, rtt_spike_scale=10.0), n)


REGIMES = {"iid": iid_cfg, "burst": burst_cfg, "cell": cell_cfg}


def _policy_stats(out) -> dict:
    valid = certified(out, "fig_transport")
    return {
        **_stats(np.asarray(out["T"])[valid]),
        "invalid": int((~valid).sum()),
        "efficiency": float(np.nanmean(out["efficiency"][valid])),
        "lost_frac": float(out["lost_frac"][valid].mean()),
        "max_backoff": float(out["max_backoff"][valid].max()),
    }


def run(reps: int = 40, R: int = R, n_helpers: int = N,
        rtt_sweep=RTT_SWEEP, regimes=None, shard: bool = False,
        policies=POLICIES) -> dict:
    regimes = dict(REGIMES if regimes is None else regimes)
    policies = tuple(policies)
    rtt_sweep = tuple(rtt_sweep)
    eng = engine.Engine(shard=shard)
    keys = simulator.batch_keys(reps)
    rows = []
    summary = {}
    rtt_meta = {"sweep": list(rtt_sweep), "regimes": {}}
    for regime, mk_cfg in regimes.items():
        regime_rows = []
        for rtt in rtt_sweep:
            cfg = mk_cfg(rtt, n_helpers)
            ch = cfg.churn
            row = {"sweep": regime, "rtt_mean": rtt, "rtt_dist": ch.rtt_dist,
                   "R": R, "N": n_helpers}
            for p in policies:
                row[p] = _policy_stats(eng.run(cfg, p, keys, R))
            regime_rows.append(row)
        rows.extend(regime_rows)
        ch0 = regimes[regime](rtt_sweep[0], n_helpers).churn
        rtt_meta["regimes"][regime] = {
            f.name: getattr(ch0, f.name)
            for f in dataclasses.fields(ch0) if f.name.startswith("rtt_")
        }
        lo, hi = regime_rows[0], regime_rows[-1]
        for p in policies:
            # Delay inflation and efficiency retention across the sweep,
            # each policy against its own zero-RTT value.
            summary[f"{regime}_{p}_T_degradation"] = (
                hi[p]["mean"] / lo[p]["mean"])
            summary[f"{regime}_{p}_eff_retention"] = (
                hi[p]["efficiency"] / lo[p]["efficiency"])
        summary[f"{regime}_invalid_total"] = sum(
            r[p]["invalid"] for r in regime_rows for p in policies)
    if "burst" in regimes and {"ccp", "tfrc_ccp"} <= set(policies):
        # The TFRC anchor: at the highest-RTT burst point, the event-rate
        # response must complete no later than the reflexive backoff.
        hi = [r for r in rows if r["sweep"] == "burst"][-1]
        summary["burst_endpoint_tfrc_vs_ccp"] = (
            hi["tfrc_ccp"]["mean"] / hi["ccp"]["mean"])
        summary["burst_endpoint_eff_tfrc_minus_ccp"] = (
            hi["tfrc_ccp"]["efficiency"] - hi["ccp"]["efficiency"])
    emit("fig_transport", rows,
         derived=";".join(f"{k}={v:.3f}" for k, v in summary.items()),
         policies=policy_meta(policies),
         extra_meta={"rtt": rtt_meta})
    return {"rows": rows, "summary": summary, "policies": policies}


if __name__ == "__main__":
    out = run()
    for r in out["rows"]:
        parts = " ".join(
            f"{p}=T{r[p]['mean']:.1f}/e{r[p]['efficiency']:.3f}"
            for p in out["policies"])
        print(f"  {r['sweep']}:rtt={r['rtt_mean']:.2f}: {parts} "
              f"(invalid={sum(r[p]['invalid'] for p in out['policies'])})")
    for k, v in out["summary"].items():
        print(f"  {k}: {v:.3f}")
