"""Beyond-paper figure: fleet saturation — tenant sojourn vs offered load.

The paper evaluates one master and a dedicated helper pool; a real edge
deployment multiplexes *tenants* over one pool.  This sweep packs an
increasing number of concurrent tasks onto a fixed pool (striped
admission, ``helpers_per_task`` recruits each, wrapping into overlap once
the pool is exhausted) and records, per policy:

  * p50 / p99 certified sojourn (completion minus release) — the knee
    where queueing delay takes off is the pool's saturation point;
  * mean helper utilization inside the fleet makespan and the Jain
    fairness of the tenants' sojourns;
  * the uncertified-task count (dropped, never averaged).

``offered`` is the recruit-weighted load ``n_tasks * helpers_per_task /
N``: 1.0 is the point where the striped placement runs out of disjoint
helpers and tenants start sharing.  CCP's interest here is that its TTI
feedback *sees* queueing (a contended helper looks slow), so it should
degrade past the knee more gracefully than the load-oblivious baselines
— that ordering at the knee is pinned by tests/test_bench_smoke.py.
"""

from __future__ import annotations

import numpy as np

from repro.core import engine, fleet, simulator

from .common import emit, policy_meta

N = 20
R = 300
TASK_SWEEP = (1, 2, 4, 8, 12)
HELPERS_PER_TASK = 5
POLICIES = ("ccp", "adaptive_rate", "hcmm", "naive")
DISCIPLINE = "fifo"


def run(reps: int = 40, task_sweep=TASK_SWEEP, R: int = R,
        n_helpers: int = N, helpers_per_task: int = HELPERS_PER_TASK,
        policies=POLICIES, discipline: str = DISCIPLINE,
        shard: bool = False) -> dict:
    del shard  # fleet reps are vmapped; device sharding is future work
    eng = engine.Engine()
    cfg = simulator.ScenarioConfig(N=n_helpers, scenario=1)
    keys = simulator.batch_keys(reps)
    h = min(helpers_per_task, n_helpers)
    rows = []
    knee = {}
    for m in task_sweep:
        fc = fleet.FleetConfig(n_tasks=m, discipline=discipline,
                               placement="striped", helpers_per_task=h)
        row = {"n_tasks": m, "offered": m * h / n_helpers, "R": R,
               "N": n_helpers, "helpers_per_task": h}
        for pol in policies:
            res = eng.run_fleet(cfg, pol, keys, R, fleet=fc)
            row[pol] = res.summary()
            if row["offered"] >= 1.0 and pol not in knee:
                knee[pol] = row[pol]["p50"]
        rows.append(row)
    derived = " ".join(
        f"{pol}_knee_p50={knee[pol]:.3f}" for pol in policies if pol in knee)
    emit("fig_fleet", rows, derived, policies=policy_meta(policies),
         extra_meta={"discipline": discipline})
    return {"rows": rows, "knee": knee}
