"""Beyond-paper figure: completion delay and efficiency under churn.

Extends the paper's adaptivity claim (§1, §6 — "adaptive to time-varying
resources") to *actual* dynamics: helpers slow down, drop out and rejoin on a
phase schedule, and packets are lost, which exercises the Algorithm 1 lines
13-14 timeout/backoff path inside the simulator scan.

Setup: Fig.-4-style heterogeneity (mu ~ U{1,3,9}, a_n = 1/mu_n) on 1-2 Mbps
links, with a churn model of mild outages/slowdowns and a swept per-packet
loss rate (the churn intensity axis).  CCP's per-helper adapted timeout
degrades gracefully toward Best; Naive's retransmission timer is statically
provisioned for the slowest helper class (it has no estimator), so every
loss on a fast helper stalls it ~mu_max/mu_min times longer than needed and
its delay blows up with the loss rate.

Anchors (checked by tests/test_simulator_dynamics.py at smaller scale):
CCP/Best stays within ~1.5x across the sweep while Naive/Best crosses ~2x.
"""

from __future__ import annotations

import numpy as np

from repro.core import simulator

from .common import _stats, emit

N = 50
R = 1000
MU_CHOICES = (1.0, 3.0, 9.0)
DROP_SWEEP = (0.0, 0.05, 0.1, 0.2, 0.3)


def churn_cfg(drop_prob: float) -> simulator.ScenarioConfig:
    return simulator.ScenarioConfig(
        N=N, scenario=1, mu_choices=MU_CHOICES, a_mode="inv_mu",
        rate_lo=1e6, rate_hi=2e6,
        churn=simulator.ChurnConfig(
            period=10.0, p_down=0.05, p_slow=0.1, slowdown=4.0,
            drop_prob=drop_prob, max_backoff=8.0,
        ),
    )


def run(reps: int = 40, drop_sweep=DROP_SWEEP) -> dict:
    rows = []
    keys = simulator.batch_keys(reps)
    for dp in drop_sweep:
        cfg = churn_cfg(dp)
        row = {"drop_prob": dp, "p_down": cfg.churn.p_down,
               "p_slow": cfg.churn.p_slow, "R": R, "N": N}
        for mode in ("ccp", "best", "naive"):
            out = simulator.run_batch(keys, cfg, R, mode)
            valid = out["valid"]
            row[mode] = {
                **_stats(out["T"][valid]),
                "invalid": int((~valid).sum()),
                "efficiency": float(np.nanmean(out["efficiency"][valid])),
                "lost_frac": float(out["lost_frac"].mean()),
                "max_backoff": float(out["max_backoff"].max()),
            }
        row["ccp_vs_best"] = row["ccp"]["mean"] / row["best"]["mean"]
        row["naive_vs_best"] = row["naive"]["mean"] / row["best"]["mean"]
        rows.append(row)
    # Degradation of each mode across the sweep, relative to its own
    # zero-churn-intensity delay (the graceful-vs-sharp comparison).
    deg = {m: rows[-1][m]["mean"] / rows[0][m]["mean"]
           for m in ("ccp", "best", "naive")}
    summary = {
        "ccp_degradation": deg["ccp"],
        "best_degradation": deg["best"],
        "naive_degradation": deg["naive"],
        "ccp_vs_best_worst": max(r["ccp_vs_best"] for r in rows),
        "naive_vs_best_worst": max(r["naive_vs_best"] for r in rows),
    }
    emit("fig_churn", rows,
         derived=";".join(f"{k}={v:.3f}" for k, v in summary.items()))
    return {"rows": rows, "summary": summary}


if __name__ == "__main__":
    out = run()
    for r in out["rows"]:
        print(f"  drop={r['drop_prob']:.2f}: ccp={r['ccp']['mean']:.1f} "
              f"best={r['best']['mean']:.1f} naive={r['naive']['mean']:.1f} "
              f"(ccp/best={r['ccp_vs_best']:.2f}, "
              f"naive/best={r['naive_vs_best']:.2f})")
    for k, v in out["summary"].items():
        print(f"  {k}: {v:.3f}")
