"""Beyond-paper figure: completion delay and efficiency under churn.

Extends the paper's adaptivity claim (§1, §6 — "adaptive to time-varying
resources") to *actual* dynamics across three loss regimes, each a sweep:

  iid    — i.i.d. per-packet loss (the PR-1 sweep), phase outages/slowdowns
  burst  — Gilbert–Elliott two-state burst loss per helper: the sweep axis
           is the good->bad transition prob, i.e. the stationary loss rate
           at a fixed burstiness (arXiv:2103.04247-style correlated fades)
  cell   — correlated whole-cell outages: a sampled subset of helpers goes
           down *simultaneously* for a log-normal-duration event; the sweep
           axis is the per-phase event probability

all of which exercise the Algorithm 1 lines 13-14 timeout/backoff path
inside the simulator scan.

Setup: Fig.-4-style heterogeneity (mu ~ U{1,3,9}, a_n = 1/mu_n) on 1-2 Mbps
links.  Four modes per point: CCP's per-helper adapted timeout degrades
gracefully toward Best; Naive's retransmission timer is statically
provisioned for the slowest helper class (it has no estimator), so every
loss on a fast helper stalls it ~mu_max/mu_min times longer than needed and
its delay blows up with the loss rate; ``naive_oracle`` gives Naive a
per-helper true-mean timer, separating its pipelining loss (still there)
from its timer-adaptation loss (gone) — the ROADMAP-requested baseline.

Uncertified reps (horizon cap hit) are *dropped and counted* per point
(``invalid``), never averaged.

Anchors (checked by tests/test_simulator_dynamics.py at smaller scale):
CCP/Best stays within ~1.5x across every sweep while Naive/Best crosses
~2x, and naive_oracle sits between CCP and Naive.
"""

from __future__ import annotations

import numpy as np

from repro.core import simulator

from .common import _stats, certified, emit

N = 50
R = 1000
MU_CHOICES = (1.0, 3.0, 9.0)
MODES = ("ccp", "best", "naive", "naive_oracle")

DROP_SWEEP = (0.0, 0.05, 0.1, 0.2, 0.3)
# GE good->bad sweep at fixed recovery (p_good=0.25) and bad-state loss 0.9:
# stationary loss = 0.9 * pb / (pb + 0.25) -> ~0, 3.4%, 9.6%, 17.4%.  Beyond
# ~20% stationary burst loss even CCP's capped backoff stops tracking Best
# (1.8x at pb=0.1), so the sweep stops where the adaptivity story is about
# timer tracking rather than raw erasure-code headroom.
BURST_SWEEP = (0.0, 0.01, 0.03, 0.06)
# Per-phase whole-cell outage event probability.
CELL_SWEEP = (0.0, 0.1, 0.25, 0.5)


def _base(churn: simulator.ChurnConfig, n: int = N) -> simulator.ScenarioConfig:
    return simulator.ScenarioConfig(
        N=n, scenario=1, mu_choices=MU_CHOICES, a_mode="inv_mu",
        rate_lo=1e6, rate_hi=2e6, churn=churn,
    )


def iid_cfg(drop_prob: float, n: int = N) -> simulator.ScenarioConfig:
    return _base(simulator.ChurnConfig(
        period=10.0, p_down=0.05, p_slow=0.1, slowdown=4.0,
        drop_prob=drop_prob, max_backoff=8.0), n)


def burst_cfg(ge_p_bad: float, n: int = N) -> simulator.ScenarioConfig:
    return _base(simulator.ChurnConfig(
        period=10.0, max_backoff=8.0,
        ge_p_bad=ge_p_bad, ge_p_good=0.25, ge_loss_good=0.0,
        ge_loss_bad=0.9), n)


def cell_cfg(p_cell: float, n: int = N) -> simulator.ScenarioConfig:
    # Mild background packet loss (fixed across the sweep) on top of the
    # swept correlated-outage rate: a cell outage stalls *everyone* on the
    # cell symmetrically, so the mode separation comes from how each timer
    # recovers around the outages — which the background loss exposes.
    return _base(simulator.ChurnConfig(
        period=5.0, max_backoff=8.0, drop_prob=0.1,
        p_cell=p_cell, cell_frac=0.6,
        outage_dist="lognormal", outage_mean=4.0, outage_sigma=0.5), n)


SWEEPS = {
    "iid": (DROP_SWEEP, iid_cfg, "drop_prob"),
    "burst": (BURST_SWEEP, burst_cfg, "ge_p_bad"),
    "cell": (CELL_SWEEP, cell_cfg, "p_cell"),
}


def _mode_stats(out: dict) -> dict:
    """Per-mode stats with uncertified reps dropped and counted."""
    valid = certified(out, "fig_churn")
    return {
        **_stats(np.asarray(out["T"])[valid]),
        "invalid": int((~valid).sum()),
        "efficiency": float(np.nanmean(out["efficiency"][valid])),
        "lost_frac": float(out["lost_frac"][valid].mean()),
        "max_backoff": float(out["max_backoff"][valid].max()),
    }


def run(reps: int = 40, sweeps=None, R: int = R, n_helpers: int = N,
        shard: bool = False) -> dict:
    sweeps = sweeps if sweeps is not None else dict(SWEEPS)
    keys = simulator.batch_keys(reps)
    rows = []
    summary = {}
    for sweep_name, (axis, mk_cfg, axis_name) in sweeps.items():
        sweep_rows = []
        for x in axis:
            cfg = mk_cfg(x, n_helpers)
            row = {"sweep": sweep_name, axis_name: x, "R": R,
                   "N": n_helpers}
            if cfg.churn.ge_enabled:
                row["ge_loss_rate"] = cfg.churn.ge_loss_rate
            for mode in MODES:
                row[mode] = _mode_stats(
                    simulator.run_batch(keys, cfg, R, mode, shard=shard)
                )
            for mode in ("ccp", "naive", "naive_oracle"):
                row[f"{mode}_vs_best"] = (
                    row[mode]["mean"] / row["best"]["mean"]
                )
            sweep_rows.append(row)
        rows.extend(sweep_rows)
        # Degradation of each mode across the sweep, relative to its own
        # zero-churn-intensity delay (the graceful-vs-sharp comparison).
        for m in MODES:
            summary[f"{sweep_name}_{m}_degradation"] = (
                sweep_rows[-1][m]["mean"] / sweep_rows[0][m]["mean"]
            )
        summary[f"{sweep_name}_ccp_vs_best_worst"] = max(
            r["ccp_vs_best"] for r in sweep_rows)
        summary[f"{sweep_name}_naive_vs_best_worst"] = max(
            r["naive_vs_best"] for r in sweep_rows)
        summary[f"{sweep_name}_naive_oracle_vs_best_worst"] = max(
            r["naive_oracle_vs_best"] for r in sweep_rows)
        summary[f"{sweep_name}_invalid_total"] = sum(
            r[m]["invalid"] for r in sweep_rows for m in MODES)
    emit("fig_churn", rows,
         derived=";".join(f"{k}={v:.3f}" for k, v in summary.items()))
    return {"rows": rows, "summary": summary}


if __name__ == "__main__":
    out = run()
    for r in out["rows"]:
        axis = [k for k in ("drop_prob", "ge_p_bad", "p_cell") if k in r][0]
        print(f"  {r['sweep']}:{axis}={r[axis]:.2f}: "
              f"ccp={r['ccp']['mean']:.1f} best={r['best']['mean']:.1f} "
              f"naive={r['naive']['mean']:.1f} "
              f"oracle={r['naive_oracle']['mean']:.1f} "
              f"(ccp/best={r['ccp_vs_best']:.2f}, "
              f"naive/best={r['naive_vs_best']:.2f}, "
              f"invalid={sum(r[m]['invalid'] for m in ('ccp', 'best', 'naive', 'naive_oracle'))})")
    for k, v in out["summary"].items():
        print(f"  {k}: {v:.3f}")
