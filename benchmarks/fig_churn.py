"""Beyond-paper figure: completion delay and efficiency under churn.

Extends the paper's adaptivity claim (§1, §6 — "adaptive to time-varying
resources") to *actual* dynamics across three loss regimes, each a sweep:

  iid    — i.i.d. per-packet loss (the PR-1 sweep), phase outages/slowdowns
  burst  — Gilbert–Elliott two-state burst loss per helper: the sweep axis
           is the good->bad transition prob, i.e. the stationary loss rate
           at a fixed burstiness (arXiv:2103.04247-style correlated fades)
  cell   — correlated whole-cell outages: a sampled subset of helpers goes
           down *simultaneously* for a log-normal-duration event; the sweep
           axis is the per-phase event probability

all of which exercise the Algorithm 1 lines 13-14 timeout/backoff path
inside the engine scan.

Setup: Fig.-4-style heterogeneity (mu ~ U{1,3,9}, a_n = 1/mu_n) on 1-2 Mbps
links.  Any subset of registered policies sweeps through the one engine
code path (``--policies ccp,hcmm,adaptive_rate``); the default set tells
the adaptivity story: CCP's per-helper adapted timeout degrades gracefully
toward Best; Naive's retransmission timer is statically provisioned for
the slowest helper class (it has no estimator), so every loss on a fast
helper stalls it ~mu_max/mu_min times longer than needed and its delay
blows up with the loss rate; ``naive_oracle`` gives Naive a per-helper
true-mean timer, separating its pipelining loss (still there) from its
timer-adaptation loss (gone); and ``adaptive_rate`` adapts the fountain
overhead to the measured loss process (arXiv:2103.04247, the ROADMAP
code-rate item), beating fixed-K CCP wherever erasures — not outages —
dominate, most visibly on the burst sweep.

Uncertified reps (horizon cap hit) are *dropped and counted* per point
(``invalid``), never averaged.

Anchors (checked by tests/test_simulator_dynamics.py and the smoke lane at
smaller scale): CCP/Best stays within ~1.5x across every sweep while
Naive/Best crosses ~2x, naive_oracle sits between CCP and Naive, and
adaptive_rate/CCP < 1 at the lossy end of the burst sweep.
"""

from __future__ import annotations

import numpy as np

from repro.core import engine, simulator

from .common import _stats, certified, emit, policy_meta

N = 50
R = 1000
MU_CHOICES = (1.0, 3.0, 9.0)
POLICIES = ("ccp", "best", "naive", "naive_oracle", "adaptive_rate")
MODES = POLICIES  # legacy alias

DROP_SWEEP = (0.0, 0.05, 0.1, 0.2, 0.3)
# GE good->bad sweep at fixed recovery (p_good=0.25) and bad-state loss 0.9:
# stationary loss = 0.9 * pb / (pb + 0.25) -> ~0, 3.4%, 9.6%, 17.4%.  Beyond
# ~20% stationary burst loss even CCP's capped backoff stops tracking Best
# (1.8x at pb=0.1), so the sweep stops where the adaptivity story is about
# timer tracking rather than raw erasure-code headroom.
BURST_SWEEP = (0.0, 0.01, 0.03, 0.06)
# Per-phase whole-cell outage event probability.
CELL_SWEEP = (0.0, 0.1, 0.25, 0.5)


def _base(churn: simulator.ChurnConfig, n: int = N) -> simulator.ScenarioConfig:
    return simulator.ScenarioConfig(
        N=n, scenario=1, mu_choices=MU_CHOICES, a_mode="inv_mu",
        rate_lo=1e6, rate_hi=2e6, churn=churn,
    )


def iid_cfg(drop_prob: float, n: int = N) -> simulator.ScenarioConfig:
    return _base(simulator.ChurnConfig(
        period=10.0, p_down=0.05, p_slow=0.1, slowdown=4.0,
        drop_prob=drop_prob, max_backoff=8.0), n)


def burst_cfg(ge_p_bad: float, n: int = N) -> simulator.ScenarioConfig:
    return _base(simulator.ChurnConfig(
        period=10.0, max_backoff=8.0,
        ge_p_bad=ge_p_bad, ge_p_good=0.25, ge_loss_good=0.0,
        ge_loss_bad=0.9), n)


def cell_cfg(p_cell: float, n: int = N) -> simulator.ScenarioConfig:
    # Mild background packet loss (fixed across the sweep) on top of the
    # swept correlated-outage rate: a cell outage stalls *everyone* on the
    # cell symmetrically, so the mode separation comes from how each timer
    # recovers around the outages — which the background loss exposes.
    return _base(simulator.ChurnConfig(
        period=5.0, max_backoff=8.0, drop_prob=0.1,
        p_cell=p_cell, cell_frac=0.6,
        outage_dist="lognormal", outage_mean=4.0, outage_sigma=0.5), n)


SWEEPS = {
    "iid": (DROP_SWEEP, iid_cfg, "drop_prob"),
    "burst": (BURST_SWEEP, burst_cfg, "ge_p_bad"),
    "cell": (CELL_SWEEP, cell_cfg, "p_cell"),
}


def _policy_stats(out) -> dict:
    """Per-policy stats with uncertified reps dropped and counted."""
    valid = certified(out, "fig_churn")
    return {
        **_stats(np.asarray(out["T"])[valid]),
        "invalid": int((~valid).sum()),
        "efficiency": float(np.nanmean(out["efficiency"][valid])),
        "lost_frac": float(out["lost_frac"][valid].mean()),
        "max_backoff": float(out["max_backoff"][valid].max()),
    }


_mode_stats = _policy_stats  # legacy alias


def run(reps: int = 40, sweeps=None, R: int = R, n_helpers: int = N,
        shard: bool = False, policies=POLICIES) -> dict:
    sweeps = sweeps if sweeps is not None else dict(SWEEPS)
    policies = tuple(policies)
    eng = engine.Engine(shard=shard)
    keys = simulator.batch_keys(reps)
    rows = []
    summary = {}
    for sweep_name, (axis, mk_cfg, axis_name) in sweeps.items():
        sweep_rows = []
        for x in axis:
            cfg = mk_cfg(x, n_helpers)
            row = {"sweep": sweep_name, axis_name: x, "R": R,
                   "N": n_helpers}
            if cfg.churn.ge_enabled:
                row["ge_loss_rate"] = cfg.churn.ge_loss_rate
            for p in policies:
                row[p] = _policy_stats(eng.run(cfg, p, keys, R))
            if "best" in policies:
                for p in policies:
                    if p != "best":
                        row[f"{p}_vs_best"] = (
                            row[p]["mean"] / row["best"]["mean"]
                        )
            sweep_rows.append(row)
        rows.extend(sweep_rows)
        # Degradation of each policy across the sweep, relative to its own
        # zero-churn-intensity delay (the graceful-vs-sharp comparison).
        for p in policies:
            summary[f"{sweep_name}_{p}_degradation"] = (
                sweep_rows[-1][p]["mean"] / sweep_rows[0][p]["mean"]
            )
            if p != "best" and "best" in policies:
                summary[f"{sweep_name}_{p}_vs_best_worst"] = max(
                    r[f"{p}_vs_best"] for r in sweep_rows)
        if "ccp" in policies and "adaptive_rate" in policies:
            # The code-rate adaptation claim: at the lossy end of the sweep
            # the adapted fountain overhead must not lose to fixed-K CCP.
            summary[f"{sweep_name}_adaptive_rate_vs_ccp"] = (
                sweep_rows[-1]["adaptive_rate"]["mean"]
                / sweep_rows[-1]["ccp"]["mean"]
            )
        summary[f"{sweep_name}_invalid_total"] = sum(
            r[p]["invalid"] for r in sweep_rows for p in policies)
    emit("fig_churn", rows,
         derived=";".join(f"{k}={v:.3f}" for k, v in summary.items()),
         policies=policy_meta(policies))
    return {"rows": rows, "summary": summary, "policies": policies}


if __name__ == "__main__":
    out = run()
    for r in out["rows"]:
        axis = [k for k in ("drop_prob", "ge_p_bad", "p_cell") if k in r][0]
        parts = " ".join(
            f"{p}={r[p]['mean']:.1f}" for p in out["policies"])
        print(f"  {r['sweep']}:{axis}={r[axis]:.2f}: {parts} "
              f"(invalid={sum(r[p]['invalid'] for p in out['policies'])})")
    for k, v in out["summary"].items():
        print(f"  {k}: {v:.3f}")
