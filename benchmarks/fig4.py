"""Paper Fig. 4: delay vs. rows with mu ~ U{1,3,9}, a_n = 1/mu_n.

Paper anchors: Sc.1 >30% over HCMM / >15% over uncoded; Sc.2 ~42% / ~73%.
"""

from __future__ import annotations

import numpy as np

from repro.configs.ccp_paper import FIG4
from repro.core import baselines, simulator, theory

from .common import emit, mc, mc_sim


def run(reps: int = 40, r_sweep=(1000, 2000, 4000, 8000),
        shard: bool = False) -> dict:
    rows = []
    summary = {}
    for sc, cfg in FIG4.items():
        for R in r_sweep:
            row = {"scenario": sc, "R": R}
            row["ccp"] = mc_sim(cfg, R, reps, "ccp", shard=shard)
            row["best"] = mc_sim(cfg, R, reps, "best", shard=shard)
            row["uncoded_mean"] = mc(
                lambda k, c, r: baselines.run_uncoded(k, c, r, rule="mean"),
                cfg, R, reps)
            row["uncoded_mu"] = mc(
                lambda k, c, r: baselines.run_uncoded(k, c, r, rule="mu"),
                cfg, R, reps)
            row["hcmm"] = mc(baselines.run_hcmm, cfg, R, reps)
            rows.append(row)
        mine = [r for r in rows if r["scenario"] == sc]
        avg = lambda f: float(np.mean([f(r) for r in mine]))
        summary[f"sc{sc}_vs_hcmm"] = avg(
            lambda r: 1 - r["ccp"]["mean"] / r["hcmm"]["mean"])
        summary[f"sc{sc}_vs_uncoded"] = avg(
            lambda r: 1 - r["ccp"]["mean"] / min(
                r["uncoded_mean"]["mean"], r["uncoded_mu"]["mean"]))
        summary[f"sc{sc}_vs_best"] = avg(
            lambda r: r["ccp"]["mean"] / r["best"]["mean"] - 1)
    emit("fig4", rows,
         derived=";".join(f"{k}={v:.3f}" for k, v in summary.items()))
    return {"rows": rows, "summary": summary}


if __name__ == "__main__":
    out = run()
    for k, v in out["summary"].items():
        print(f"  {k}: {v:+.1%}")
