"""Paper Fig. 4: delay vs. rows with mu ~ U{1,3,9}, a_n = 1/mu_n.

Every policy row runs through the vmapped engine via the policy registry
(the uncoded/HCMM block baselines included).

Paper anchors: Sc.1 >30% over HCMM / >15% over uncoded; Sc.2 ~42% / ~73%.
"""

from __future__ import annotations

import numpy as np

from repro.configs.ccp_paper import FIG4

from .common import emit, mc_policy, policy_meta

POLICIES = ("ccp", "best", "uncoded_mean", "uncoded_mu", "hcmm")


def run(reps: int = 40, r_sweep=(1000, 2000, 4000, 8000),
        shard: bool = False, policies=POLICIES) -> dict:
    policies = tuple(policies)
    rows = []
    summary = {}
    for sc, cfg in FIG4.items():
        for R in r_sweep:
            row = {"scenario": sc, "R": R}
            for p in policies:
                row[p] = mc_policy(cfg, R, reps, p, shard=shard)
            rows.append(row)
        mine = [r for r in rows if r["scenario"] == sc]
        avg = lambda f: float(np.mean([f(r) for r in mine]))
        has = lambda *ps: all(p in policies for p in ps)
        if has("ccp", "hcmm"):
            summary[f"sc{sc}_vs_hcmm"] = avg(
                lambda r: 1 - r["ccp"]["mean"] / r["hcmm"]["mean"])
        if has("ccp", "uncoded_mean", "uncoded_mu"):
            summary[f"sc{sc}_vs_uncoded"] = avg(
                lambda r: 1 - r["ccp"]["mean"] / min(
                    r["uncoded_mean"]["mean"], r["uncoded_mu"]["mean"]))
        if has("ccp", "best"):
            summary[f"sc{sc}_vs_best"] = avg(
                lambda r: r["ccp"]["mean"] / r["best"]["mean"] - 1)
    emit("fig4", rows,
         derived=";".join(f"{k}={v:.3f}" for k, v in summary.items()),
         policies=policy_meta(policies))
    return {"rows": rows, "summary": summary}


if __name__ == "__main__":
    out = run()
    for k, v in out["summary"].items():
        print(f"  {k}: {v:+.1%}")
