"""Aggregate the dry-run JSON cells into the §Roofline table.

Reads experiments/dryrun/*.json (written by repro.launch.dryrun) and prints
the per-(arch x shape x mesh) roofline terms, dominant bottleneck, useful-
flops ratio, and a one-line what-would-move-it hint.
"""

from __future__ import annotations

import json
import pathlib

from .common import emit

DRYRUN_DIR = pathlib.Path(__file__).resolve().parent.parent / "experiments" / "dryrun"

HINTS = {
    "compute": "raise arithmetic efficiency: fuse encode into matmul, drop remat recompute, larger per-device tiles",
    "memory": "cut HBM traffic: Pallas flash attention (no materialized scores), fp32->bf16 intermediates, fuse norms into matmuls",
    "collective": "shrink wire bytes: reduce-scatter+all-gather instead of all-reduce, overlap grad AR with backward, quantized (bf16) gradient AR",
}


def run() -> dict:
    rows = []
    for p in sorted(DRYRUN_DIR.glob("*.json")):
        d = json.loads(p.read_text())
        if d.get("status") != "ok":
            rows.append({
                "arch": d["arch"], "shape": d["shape"], "mesh": d["mesh"],
                "status": d["status"],
                "reason": d.get("skip_reason") or d.get("error"),
            })
            continue
        r = d["roofline"]
        rows.append({
            "arch": d["arch"], "shape": d["shape"], "mesh": d["mesh"],
            "status": "ok",
            "compute_s": r["compute_s"], "memory_s": r["memory_s"],
            "collective_s": r["collective_s"], "dominant": r["dominant"],
            "model_flops": r["model_flops"], "hlo_flops": r["hlo_flops"],
            "useful_ratio": r["useful_flops_ratio"],
            "roofline_fraction": r["roofline_fraction"],
            "hint": HINTS[r["dominant"]],
        })
    ok = [r for r in rows if r["status"] == "ok"]
    n_skip = sum(r["status"] == "skip" for r in rows)
    n_fail = sum(r["status"] == "fail" for r in rows)
    worst = min(ok, key=lambda r: r["roofline_fraction"] or 1) if ok else None
    emit("roofline", rows,
         derived=f"cells_ok={len(ok)};skip={n_skip};fail={n_fail};"
                 f"worst={worst['arch']}/{worst['shape'] if worst else ''}")
    return {"rows": rows}


if __name__ == "__main__":
    out = run()
    fmt = "{:24s} {:12s} {:6s} {:>9s} {:>9s} {:>9s} {:>10s} {:>7s}"
    print(fmt.format("arch", "shape", "mesh", "compute", "memory",
                     "collect", "dominant", "roof%"))
    for r in out["rows"]:
        if r["status"] != "ok":
            print(fmt.format(r["arch"], r["shape"], r["mesh"], "-", "-", "-",
                             r["status"], "-"))
            continue
        print(fmt.format(
            r["arch"], r["shape"], r["mesh"],
            f"{r['compute_s']*1e3:.1f}ms", f"{r['memory_s']*1e3:.1f}ms",
            f"{r['collective_s']*1e3:.1f}ms", r["dominant"],
            f"{(r['roofline_fraction'] or 0)*100:.1f}",
        ))
