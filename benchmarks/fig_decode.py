"""Beyond-paper figure: measured LT decode overhead and honest completion.

The paper's O(R) Raptor argument (§2) treats the fountain code as an ideal
MDS abstraction — any R+K packets complete the task.  PR 4's
decoder-in-the-loop subsystem measures what the code *actually* does:

  * ``rateless_ccp`` completes when the incremental peeling decode
    succeeds, so its per-rep overhead ``r_n.sum() - R`` (arrivals the
    decoder consumed beyond the R sources) is the *measured* LT overhead
    distribution — swept here against the i.i.d. loss rate;
  * the gap ``rateless_ccp / ccp`` is the honesty gap of the packet
    counter: how much completion delay the idealized (R+K)-count rule
    hides at each loss level;
  * ``adaptive_rate_fb`` shows what decoder feedback buys: the adapted
    send overhead plus stop-on-decode ("drop K") closes part of that gap;
  * every row also carries the *offline* reference — an arrival-order
    Monte-Carlo of the same parity pool
    (:func:`repro.core.decode.offline_overhead_samples`) and the generic
    robust-soliton failure statistics
    (:func:`repro.core.fountain.decode_failure_prob`) — so the in-engine
    measurement is sanity-anchored row by row.

Helpers are homogeneous (mu = 2.0) so the overhead reflects the *loss
process*, not straggler reordering; the heterogeneous reordering cost is
visible in fig_churn via the same policies.  Uncertified reps are dropped
and counted, never averaged.
"""

from __future__ import annotations

import numpy as np

from repro.core import decode, engine, fountain, simulator

from .common import _stats, certified, emit, policy_meta

N = 20
R = 400
DROP_SWEEP = (0.0, 0.1, 0.2, 0.3)
POLICIES = ("ccp", "rateless_ccp", "adaptive_rate_fb")


def drop_cfg(drop_prob: float, n: int = N) -> simulator.ScenarioConfig:
    churn = (simulator.ChurnConfig(drop_prob=drop_prob, max_backoff=8.0)
             if drop_prob > 0 else None)
    return simulator.ScenarioConfig(
        N=n, scenario=1, mu_choices=(2.0,), churn=churn)


def _overhead_stats(res, R: int, valid) -> dict:
    ov = (np.asarray(res["r_n"]).sum(axis=1) - R)[valid]
    return {
        **_stats(ov.astype(np.float64)),
        "p95": float(np.percentile(ov, 95)),
        "frac_mean": float(ov.mean() / R),
    }


def run(reps: int = 40, sweep=DROP_SWEEP, R: int = R, n_helpers: int = N,
        shard: bool = False, offline_trials: int = 8) -> dict:
    eng = engine.Engine(shard=shard)
    keys = simulator.batch_keys(reps)
    code = decode.make_decoder_code(R)
    rows = []
    summary = {}
    for p in sweep:
        cfg = drop_cfg(p, n_helpers)
        row = {"drop_prob": p, "R": R, "N": n_helpers}
        results = {}
        for pol in POLICIES:
            out = eng.run(cfg, pol, keys, R)
            valid = certified(out, f"fig_decode policy={pol!r} p={p}")
            results[pol] = (out, valid)
            row[pol] = {
                **_stats(np.asarray(out["T"])[valid]),
                "invalid": int((~valid).sum()),
            }
            if pol != "ccp":
                row[pol]["overhead"] = _overhead_stats(out, R, valid)
        # Cross-policy ratios over the *intersection* of certified reps —
        # per-policy stats above drop each policy's own invalid reps, but a
        # ratio of means over different rep subsets would silently compare
        # different Monte-Carlo ensembles (the bias this figure exists to
        # expose elsewhere).
        both = np.logical_and.reduce([v for _, v in results.values()])
        n_both = int(both.sum())
        if n_both == 0:
            raise RuntimeError(
                f"fig_decode p={p}: no rep certified for every policy")
        mean_on = {pol: float(np.asarray(out["T"])[both].mean())
                   for pol, (out, _v) in results.items()}
        row["compared_reps"] = n_both
        row["counter_gap"] = mean_on["rateless_ccp"] / mean_on["ccp"]
        row["feedback_gain"] = (
            mean_on["adaptive_rate_fb"] / mean_on["rateless_ccp"])
        # offline anchors: same pool code, arrival-order MC + the generic
        # robust-soliton failure probability at the matched loss level
        off = decode.offline_overhead_samples(
            R, code, p, trials=offline_trials, seed=7)
        ok = off[off >= 0]
        row["offline"] = {
            "overhead_mean": float(ok.mean()) if ok.size else None,
            "overhead_frac": float(ok.mean() / R) if ok.size else None,
            "pool_undecodable": int((off < 0).sum()),
            "trials": int(off.size),
        }
        K = R // 2
        row["soliton_failure"] = fountain.decode_failure_prob(
            R, K, int(np.ceil(p * (R + K))), trials=10, seed=0)
        rows.append(row)
    for p, row in zip(sweep, rows):
        summary[f"gap_p{p}"] = row["counter_gap"]
        summary[f"ov_frac_p{p}"] = row["rateless_ccp"]["overhead"]["frac_mean"]
        summary[f"fb_gain_p{p}"] = row["feedback_gain"]
    emit("fig_decode", rows,
         derived=";".join(f"{k}={v:.3f}" for k, v in summary.items()),
         policies=policy_meta(POLICIES))
    return {"rows": rows, "summary": summary}


if __name__ == "__main__":
    out = run(reps=8)
    for r in out["rows"]:
        ov = r["rateless_ccp"]["overhead"]
        print(f"  p={r['drop_prob']:.2f}: ccp={r['ccp']['mean']:.1f}s "
              f"rateless={r['rateless_ccp']['mean']:.1f}s "
              f"(gap {r['counter_gap']:.2f}x, overhead "
              f"{ov['frac_mean']:.1%} of R, offline "
              f"{r['offline']['overhead_frac']}) "
              f"fb_gain={r['feedback_gain']:.2f}")
