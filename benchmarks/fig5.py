"""Paper Fig. 5: CCP vs. Best and Naive on slow links (0.1-0.2 Mbps, N=10).

Anchor: T_naive - T_ccp grows with R; T_ccp - T_best stays small/flat.
"""

from __future__ import annotations

from repro.configs.ccp_paper import FIG5

from .common import emit, mc_policy, policy_meta

POLICIES = ("ccp", "best", "naive")


def run(reps: int = 30, r_sweep=(200, 400, 800, 1600),
        shard: bool = False, policies=POLICIES) -> dict:
    policies = tuple(policies)
    rows = []
    for R in r_sweep:
        row = {"R": R}
        for p in policies:
            row[p] = mc_policy(FIG5, R, reps, p, shard=shard)
        if {"ccp", "best", "naive"} <= set(policies):
            row["gap_naive"] = row["naive"]["mean"] - row["ccp"]["mean"]
            row["gap_best"] = row["ccp"]["mean"] - row["best"]["mean"]
        rows.append(row)
    if "gap_naive" not in rows[0]:
        emit("fig5", rows, derived="", policies=policy_meta(policies))
        return {"rows": rows}
    growth = rows[-1]["gap_naive"] / max(rows[0]["gap_naive"], 1e-9)
    flat = rows[-1]["gap_best"] / max(rows[0]["gap_best"], 1e-9)
    emit("fig5", rows,
         derived=f"naive_gap_growth={growth:.2f};best_gap_growth={flat:.2f}",
         policies=policy_meta(policies))
    return {"rows": rows, "naive_gap_growth": growth, "best_gap_growth": flat}


if __name__ == "__main__":
    out = run()
    print(f"  naive-gap growth x{out['naive_gap_growth']:.1f}, "
          f"best-gap growth x{out['best_gap_growth']:.1f}")
