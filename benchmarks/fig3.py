"""Paper Fig. 3: task completion delay vs. number of rows, Scenarios 1 & 2.

Setup: N=100 helpers, a_n=0.5, mu_n ~ U{1,2,4}, 10-20 Mbps links, 5% coding
overhead; CCP / Best / Optimum-Analysis / Uncoded(mean, mu) / HCMM.

Paper anchors: Sc.1 ~30% better than HCMM, ~24% better than uncoded, and
uncoded beats HCMM;  Sc.2 ~40% / ~69%, and HCMM beats uncoded.
"""

from __future__ import annotations

import numpy as np

from repro.configs.ccp_paper import FIG3
from repro.core import baselines, simulator, theory

from .common import emit, mc, mc_sim


def run(reps: int = 40, r_sweep=(1000, 2000, 4000, 8000),
        shard: bool = False) -> dict:
    rows = []
    summary = {}
    for sc, cfg in FIG3.items():
        for R in r_sweep:
            K = cfg.K(R)
            row = {"scenario": sc, "R": R}
            row["ccp"] = mc_sim(cfg, R, reps, "ccp", shard=shard)
            row["best"] = mc_sim(cfg, R, reps, "best", shard=shard)
            row["uncoded_mean"] = mc(
                lambda k, c, r: baselines.run_uncoded(k, c, r, rule="mean"),
                cfg, R, reps)
            row["uncoded_mu"] = mc(
                lambda k, c, r: baselines.run_uncoded(k, c, r, rule="mu"),
                cfg, R, reps)
            row["hcmm"] = mc(baselines.run_hcmm, cfg, R, reps)
            # Optimum Analysis: eq. (27) for Sc.1; Thm-3 bound for Sc.2
            topts = []
            import jax
            for r in range(reps):
                o = simulator.draw_helpers(
                    jax.random.PRNGKey(r), cfg)
                mu, a = np.asarray(o[0]), np.asarray(o[1])
                topts.append(theory.t_opt_model1(R, K, a, mu))
            row["optimum"] = {"mean": float(np.mean(topts)),
                              "std": float(np.std(topts))}
            rows.append(row)
        # improvement summary averaged over the R sweep (the paper's "in
        # average, X% improvement" convention)
        mine = [r for r in rows if r["scenario"] == sc]
        avg = lambda f: float(np.mean([f(r) for r in mine]))
        summary[f"sc{sc}_vs_hcmm"] = avg(
            lambda r: 1 - r["ccp"]["mean"] / r["hcmm"]["mean"])
        summary[f"sc{sc}_vs_uncoded"] = avg(
            lambda r: 1 - r["ccp"]["mean"] / min(
                r["uncoded_mean"]["mean"], r["uncoded_mu"]["mean"]))
        summary[f"sc{sc}_vs_best"] = avg(
            lambda r: r["ccp"]["mean"] / r["best"]["mean"] - 1)
        summary[f"sc{sc}_vs_optimum"] = avg(
            lambda r: r["ccp"]["mean"] / r["optimum"]["mean"] - 1)
    emit("fig3", rows,
         derived=";".join(f"{k}={v:.3f}" for k, v in summary.items()))
    return {"rows": rows, "summary": summary}


if __name__ == "__main__":
    out = run()
    for k, v in out["summary"].items():
        print(f"  {k}: {v:+.1%}")
