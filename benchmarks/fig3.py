"""Paper Fig. 3: task completion delay vs. number of rows, Scenarios 1 & 2.

Setup: N=100 helpers, a_n=0.5, mu_n ~ U{1,2,4}, 10-20 Mbps links, 5% coding
overhead; CCP / Best / Optimum-Analysis / Uncoded(mean, mu) / HCMM — every
policy row now runs through the one vmapped (optionally device-sharded)
engine path via the policy registry, including the uncoded/HCMM block
baselines that used to take a sequential NumPy side path.

Paper anchors: Sc.1 ~30% better than HCMM, ~24% better than uncoded, and
uncoded beats HCMM;  Sc.2 ~40% / ~69%, and HCMM beats uncoded.
"""

from __future__ import annotations

import numpy as np

from repro.configs.ccp_paper import FIG3
from repro.core import simulator, theory

from .common import emit, mc_policy, policy_meta

POLICIES = ("ccp", "best", "uncoded_mean", "uncoded_mu", "hcmm")


def run(reps: int = 40, r_sweep=(1000, 2000, 4000, 8000),
        shard: bool = False, policies=POLICIES) -> dict:
    policies = tuple(policies)
    rows = []
    summary = {}
    for sc, cfg in FIG3.items():
        for R in r_sweep:
            K = cfg.K(R)
            row = {"scenario": sc, "R": R}
            for p in policies:
                row[p] = mc_policy(cfg, R, reps, p, shard=shard)
            # Optimum Analysis: eq. (27) for Sc.1; Thm-3 bound for Sc.2
            topts = []
            import jax
            for r in range(reps):
                o = simulator.draw_helpers(
                    jax.random.PRNGKey(r), cfg)
                mu, a = np.asarray(o[0]), np.asarray(o[1])
                topts.append(theory.t_opt_model1(R, K, a, mu))
            row["optimum"] = {"mean": float(np.mean(topts)),
                              "std": float(np.std(topts))}
            rows.append(row)
        # improvement summary averaged over the R sweep (the paper's "in
        # average, X% improvement" convention)
        mine = [r for r in rows if r["scenario"] == sc]
        avg = lambda f: float(np.mean([f(r) for r in mine]))
        has = lambda *ps: all(p in policies for p in ps)
        if has("ccp", "hcmm"):
            summary[f"sc{sc}_vs_hcmm"] = avg(
                lambda r: 1 - r["ccp"]["mean"] / r["hcmm"]["mean"])
        if has("ccp", "uncoded_mean", "uncoded_mu"):
            summary[f"sc{sc}_vs_uncoded"] = avg(
                lambda r: 1 - r["ccp"]["mean"] / min(
                    r["uncoded_mean"]["mean"], r["uncoded_mu"]["mean"]))
        if has("ccp", "best"):
            summary[f"sc{sc}_vs_best"] = avg(
                lambda r: r["ccp"]["mean"] / r["best"]["mean"] - 1)
        if has("ccp"):
            summary[f"sc{sc}_vs_optimum"] = avg(
                lambda r: r["ccp"]["mean"] / r["optimum"]["mean"] - 1)
    emit("fig3", rows,
         derived=";".join(f"{k}={v:.3f}" for k, v in summary.items()),
         policies=policy_meta(policies))
    return {"rows": rows, "summary": summary}


if __name__ == "__main__":
    out = run()
    for k, v in out["summary"].items():
        print(f"  {k}: {v:+.1%}")
