"""Kernel-level roofline accounting for the Pallas hot spots (beyond-paper).

CPU wall-times of interpret-mode kernels are meaningless for TPU, so this
benchmark reports the *structural* roofline terms: FLOPs, HBM bytes moved
(fused vs. unfused), and arithmetic intensity — the quantities the §Perf
iterations act on — plus a correctness spot-check against the oracle, and
the wall-clock speedup of the vmapped Monte-Carlo engine over the
sequential per-rep loop (a real timing: both paths run the same jitted
simulation, so the ratio is meaningful even on CPU).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine, fountain, simulator
from repro.kernels.coded_matmul import coded_matmul, coded_matmul_ref
from repro.kernels.coded_matmul.ops import flops as cm_flops
from repro.kernels.flash_attention.ops import attention_flops

from .common import emit

HBM_BW = 819e9
PEAK = 197e12


def run() -> dict:
    rows = []
    # --- coded matmul: production-ish shapes ------------------------------
    for (R, K, bm, kdim, ndim) in ((32, 8, 256, 4096, 4096),
                                   (64, 16, 128, 8192, 1024)):
        code = fountain.make_lt_code(R, K, seed=0)
        d_mean = float(code.degrees().mean())
        f = cm_flops(R, K, bm, kdim, ndim, d_mean)
        ai_fused = f["matmul_flops"] / f["hbm_bytes_fused"]
        ai_unfused = f["matmul_flops"] / f["hbm_bytes_unfused"]
        rows.append({
            "kernel": "coded_matmul", "R": R, "K": K, "bm": bm,
            "k": kdim, "n": ndim,
            "matmul_flops": f["matmul_flops"],
            "encode_flops": f["encode_flops"],
            "bytes_fused": f["hbm_bytes_fused"],
            "bytes_unfused": f["hbm_bytes_unfused"],
            "fusion_byte_saving": 1 - f["hbm_bytes_fused"] / f["hbm_bytes_unfused"],
            "arith_intensity_fused": ai_fused,
            "arith_intensity_unfused": ai_unfused,
            "compute_bound_fused": ai_fused > PEAK / HBM_BW,
        })
    # correctness spot check (small, interpret mode)
    code = fountain.make_lt_code(8, 4, seed=1)
    a = jax.random.normal(jax.random.PRNGKey(0), (8 * 16, 64))
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 32))
    out = coded_matmul(a, x, jnp.asarray(code.idx), jnp.asarray(code.mask),
                       bm=16, bk=32, bn=16, use_pallas=True, interpret=True)
    ref = coded_matmul_ref(a, x, jnp.asarray(code.idx), jnp.asarray(code.mask), 16)
    max_err = float(jnp.abs(out - ref).max())

    # --- lt_decode: round-levelized peeling payload decode -----------------
    # Structural accounting per plan: the kernel executes one pallas_call
    # per dependency level (fountain.plan_rounds), so the device-side
    # critical path is the level count, not the O(R) sequential step count
    # of apply_decode_plan.  Pure VPU + DMA — memory bound by design.
    from repro.core import decode as decode_mod
    from repro.kernels.lt_decode import lt_decode

    for (R, K, bm, cols, n_lost) in ((64, 64, 256, 4096, 8),
                                     (256, 256, 64, 8192, 32)):
        dcode = decode_mod.make_decoder_code(R, K)
        rng = np.random.default_rng(R)
        lost = rng.choice(R, size=n_lost, replace=False)
        keep = np.setdiff1d(np.arange(R + K), lost)
        plan = fountain.peel_decode_plan(dcode, keep)
        if plan is None:
            # Peeling stall on this sampled loss pattern: record it instead
            # of structural numbers (the decode would take the dense path).
            rows.append({"kernel": "lt_decode", "R": R, "K": K, "bm": bm,
                         "cols": cols, "lost": n_lost, "peel_stalled": True})
            continue
        rounds = fountain.plan_rounds(plan)
        d_mean = float(np.mean([
            (rnd.nbr_coef != 0).sum(axis=1).mean() for rnd in rounds
        ])) if rounds else 0.0
        n_peel = sum(rnd.size for rnd in rounds)
        # per recovered source: read 1 coded + d_mean src tiles, write 1
        bytes_moved = 4.0 * bm * cols * (n_peel * (2.0 + d_mean)
                                         + 2.0 * plan.direct_src.size)
        flops = 2.0 * bm * cols * n_peel * (d_mean + 1.0)
        rows.append({
            "kernel": "lt_decode", "R": R, "K": K, "bm": bm, "cols": cols,
            "lost": n_lost, "plan_steps": plan.n_peeled,
            "rounds": len(rounds), "peel_d_mean": d_mean,
            "hbm_bytes": bytes_moved, "flops": flops,
            "arith_intensity": flops / bytes_moved,
            "seq_step_saving": 1.0 - len(rounds) / max(plan.n_peeled, 1),
        })
    # correctness spot check vs the jnp reference (small, interpret mode)
    dcode = decode_mod.make_decoder_code(12, 12, d_max=8)
    keep = np.setdiff1d(np.arange(24), [2, 7, 11])
    plan = fountain.peel_decode_plan(dcode, keep)
    blocks = jax.random.normal(jax.random.PRNGKey(2), (12 * 8, 32))
    from repro.kernels.lt_encode import lt_encode_code
    coded = lt_encode_code(blocks, dcode, bm=8)
    crx = coded.reshape(24, 8, 32)[keep].reshape(-1, 32)
    dec_ref = lt_decode(crx, plan, bm=8)
    dec_ker = lt_decode(crx, plan, bm=8, use_pallas=True, interpret=True,
                        bc=32)
    lt_decode_max_err = float(jnp.abs(dec_ker - dec_ref).max())
    lt_decode_recon_err = float(jnp.abs(dec_ref - blocks).max())

    # --- flash attention: assigned-shape accounting ------------------------
    for (tag, B, Hq, Tq, Tk, D, window) in (
        ("gemma2 train local", 32, 32, 4096, 4096, 128, 4096),
        ("gemma2 prefill32k global", 32, 32, 32768, 32768, 128, None),
        ("nemo decode32k", 128, 32, 1, 32768, 128, None),
    ):
        f = attention_flops(B, Hq, Tq, Tk, D, causal=True, window=window)
        io = 2.0 * B * (Hq * Tq * D * 2 + 2 * (Hq * Tk * D * 2) // max(Hq // 8, 1))
        naive_bytes = io + 4.0 * B * Hq * Tq * Tk  # materialized scores fp32
        rows.append({
            "kernel": "flash_attention", "case": tag,
            "flops": f, "bytes_flash": io, "bytes_naive": naive_bytes,
            "hbm_saving": 1 - io / naive_bytes,
        })
    # --- batched vs sequential Monte-Carlo (engine.Engine) -----------------
    # Two regimes: fig5-style (N=10, per-rep horizons vary with the mu draw,
    # so the sequential loop keeps re-tracing per horizon bucket — the shared
    # bucketed horizon removes that entirely) and fig3-style (N=100, stable
    # horizon; the win is one dispatch instead of ``reps``).
    speedups = {}
    eng = engine.Engine()
    for tag, cfg, R in (
        ("fig5", simulator.ScenarioConfig(N=10, scenario=2,
                                          rate_lo=0.1e6, rate_hi=0.2e6), 400),
        ("fig3", simulator.ScenarioConfig(N=100, scenario=1), 2000),
    ):
        reps = 40
        keys = simulator.batch_keys(reps)
        # Warm BOTH paths so the ratio is steady-state, not compile time.
        # The fig5 sequential case still re-traces mid-loop — per-rep
        # horizons vary with the mu draw, so one warm call only covers one
        # bucket; that recurring retrace cost is precisely what the shared
        # bucketed horizon removes.
        batched = eng.run(cfg, "ccp", keys, R)
        eng.run_one(jax.random.PRNGKey(0), cfg, "ccp", R)
        t0 = time.perf_counter()
        batched = eng.run(cfg, "ccp", keys, R)
        t_batch = time.perf_counter() - t0
        t0 = time.perf_counter()
        seq_t = [eng.run_one(keys[r], cfg, "ccp", R)["T"]
                 for r in range(reps)]
        t_seq = time.perf_counter() - t0
        speedups[tag] = t_seq / max(t_batch, 1e-9)
        rows.append({
            "kernel": "mc_batch", "case": tag, "reps": reps, "R": R,
            "N": cfg.N, "M": batched["M"],
            "t_sequential_s": t_seq, "t_batched_s": t_batch,
            "speedup": speedups[tag],
            "mc_mean_abs_gap": abs(float(np.mean(batched["T"]))
                                   - float(np.mean(seq_t))),
        })

    # --- decoder-in-the-loop engine overhead --------------------------------
    # What the incremental peeling decoder costs inside the scan (absorb +
    # peel fixpoint per step + binary-search finalize) relative to the
    # packet-counting policy on the same draws.
    cfg_d = simulator.ScenarioConfig(N=20, scenario=1, mu_choices=(2.0,))
    keys_d = simulator.batch_keys(8)
    for pol in ("ccp", "rateless_ccp"):  # warm both compile caches
        eng.run(cfg_d, pol, keys_d, 300)
    t0 = time.perf_counter()
    eng.run(cfg_d, "ccp", keys_d, 300)
    t_counter = time.perf_counter() - t0
    t0 = time.perf_counter()
    eng.run(cfg_d, "rateless_ccp", keys_d, 300)
    t_decode = time.perf_counter() - t0
    decoder_cost = t_decode / max(t_counter, 1e-9)
    rows.append({
        "kernel": "mc_decoder_in_loop", "reps": 8, "R": 300, "N": 20,
        "t_counter_s": t_counter, "t_decode_s": t_decode,
        "cost_ratio": decoder_cost,
    })

    # --- device-sharded vs single-device batched MC ------------------------
    # On the 1-device CI box this measures shard_map overhead (~1x); on a
    # real mesh it is the raw-parallelism win ROADMAP asked for.  Results
    # must be bitwise identical either way (per-rep lanes are independent).
    cfg, R, reps = simulator.ScenarioConfig(N=100, scenario=1), 2000, 40
    keys = simulator.batch_keys(reps)
    un = eng.run(cfg, "ccp", keys, R)
    sh = eng.run(cfg, "ccp", keys, R, shard=True)
    t0 = time.perf_counter()
    un = eng.run(cfg, "ccp", keys, R)
    t_un = time.perf_counter() - t0
    t0 = time.perf_counter()
    sh = eng.run(cfg, "ccp", keys, R, shard=True)
    t_sh = time.perf_counter() - t0
    shard_eq = bool(np.array_equal(un["T"], sh["T"]))
    shard_speedup = t_un / max(t_sh, 1e-9)
    rows.append({
        "kernel": "mc_batch_shard", "devices": jax.local_device_count(),
        "reps": reps, "R": R, "t_unsharded_s": t_un, "t_sharded_s": t_sh,
        "speedup": shard_speedup, "bitwise_equal": shard_eq,
    })

    emit("kernel_bench", rows,
         derived=f"coded_matmul_max_err={max_err:.2e};"
                 f"lt_decode_max_err={lt_decode_max_err:.2e};"
                 f"lt_decode_recon_err={lt_decode_recon_err:.2e};"
                 f"mc_decoder_cost={decoder_cost:.2f}x;"
                 + ";".join(f"mc_batch_speedup_{k}={v:.1f}x"
                            for k, v in speedups.items())
                 + f";mc_shard_speedup={shard_speedup:.2f}x"
                 + f";mc_shard_bitwise_equal={shard_eq}")
    return {"rows": rows, "max_err": max_err,
            "lt_decode_max_err": lt_decode_max_err,
            "decoder_cost": decoder_cost, "mc_batch_speedups": speedups,
            "mc_shard_speedup": shard_speedup, "mc_shard_equal": shard_eq}


if __name__ == "__main__":
    out = run()
    for r in out["rows"]:
        print(" ", {k: (round(v, 4) if isinstance(v, float) else v)
                    for k, v in list(r.items())[:6]})
