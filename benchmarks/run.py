"""Benchmark harness: one entry per paper table/figure + framework extras.

Prints ``name,us_per_call,derived`` CSV rows (us_per_call is '-' for
simulation benchmarks whose deliverable is the derived statistics).

  fig3        — delay vs rows, Scenarios 1/2 (paper Fig. 3)
  fig4        — delay vs rows, mu in {1,3,9} (paper Fig. 4)
  fig5        — CCP vs best/naive gaps on slow links (paper Fig. 5)
  fig_churn   — delay/efficiency under churn + loss (beyond-paper, §1 claim)
  efficiency  — measured vs eq.(12) efficiency (paper §6 table)
  overhead    — fountain codec failure prob + O(R) timing (paper §2 claims)
  kernel      — Pallas hot-spot roofline accounting + batched-MC speedup
  roofline    — aggregate the dry-run cells (EXPERIMENTS.md §Roofline)

Run everything:  PYTHONPATH=src python -m benchmarks.run
Subset:          PYTHONPATH=src python -m benchmarks.run --only fig3,fig5
Fast smoke:      PYTHONPATH=src python -m benchmarks.run --fast
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of benchmark names")
    ap.add_argument("--fast", action="store_true",
                    help="reduced rep counts (CI smoke)")
    args = ap.parse_args()

    from . import (efficiency, fig3, fig4, fig5, fig_churn, kernel_bench,
                   overhead, roofline_report)

    reps = 8 if args.fast else 40
    sweep = (500, 1000) if args.fast else (1000, 2000, 4000, 8000)
    jobs = {
        "fig3": lambda: fig3.run(reps=reps, r_sweep=sweep),
        "fig4": lambda: fig4.run(reps=reps, r_sweep=sweep),
        "fig5": lambda: fig5.run(reps=max(reps // 2, 5),
                                 r_sweep=(200, 400) if args.fast
                                 else (200, 400, 800, 1600)),
        "fig_churn": lambda: fig_churn.run(
            reps=reps,
            drop_sweep=(0.0, 0.1, 0.3) if args.fast else fig_churn.DROP_SWEEP),
        "efficiency": lambda: efficiency.run(reps=4 if args.fast else 20,
                                             R=2000 if args.fast else 8000),
        "overhead": overhead.run,
        "kernel": kernel_bench.run,
        "roofline": roofline_report.run,
    }
    only = set(args.only.split(",")) if args.only else set(jobs)
    failed = []
    print("name,us_per_call,derived")
    for name, job in jobs.items():
        if name not in only:
            continue
        t0 = time.time()
        try:
            job()
            print(f"# {name} done in {time.time() - t0:.1f}s", file=sys.stderr)
        except Exception:
            traceback.print_exc()
            failed.append(name)
    if failed:
        print(f"# FAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
