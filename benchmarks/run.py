"""Benchmark harness: one entry per paper table/figure + framework extras.

Prints ``name,us_per_call,derived`` CSV rows (us_per_call is '-' for
simulation benchmarks whose deliverable is the derived statistics).

  fig3        — delay vs rows, Scenarios 1/2 (paper Fig. 3)
  fig4        — delay vs rows, mu in {1,3,9} (paper Fig. 4)
  fig5        — CCP vs best/naive gaps on slow links (paper Fig. 5)
  fig_churn   — delay/efficiency under i.i.d./burst/cell-outage churn
                (beyond-paper, §1 claim; includes naive+oracle-timer)
  fig_decode  — measured LT decode overhead + counter-vs-decoder honesty
                gap across a loss sweep (beyond-paper, PR-4 decoder loop)
  fig_fleet   — multi-tenant saturation sweep: p50/p99 sojourn, helper
                utilization and Jain fairness vs offered load
                (beyond-paper, PR-7 fleet engine)
  fig_transport — delay/efficiency vs mean feedback RTT across iid/burst/
                cell churn; the price of delayed ACK/NACK observation
                (beyond-paper, PR-8 transport layer)
  efficiency  — measured vs eq.(12) efficiency (paper §6 table)
  overhead    — fountain codec failure prob + O(R) timing (paper §2 claims)
  kernel      — Pallas hot-spot roofline accounting + batched-MC speedup
  roofline    — aggregate the dry-run cells (EXPERIMENTS.md §Roofline)

Run everything:  PYTHONPATH=src python -m benchmarks.run
Subset:          PYTHONPATH=src python -m benchmarks.run --only fig3,fig5
Fast smoke:      PYTHONPATH=src python -m benchmarks.run --fast
Test-lane smoke: PYTHONPATH=src python -m benchmarks.run --smoke --only fig_churn
Device-sharded:  PYTHONPATH=src python -m benchmarks.run --shard --reps 64
Policy subset:   PYTHONPATH=src python -m benchmarks.run --only fig_churn \
                     --policies ccp,hcmm,adaptive_rate

``--policies`` routes any subset of registered policies (see
``repro.core.policies.names()``) through the figure sweeps; the ``--smoke``
lane defaults to *every* registered policy so a policy that breaks under
jit/vmap fails the fast test lane.
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of benchmark names")
    ap.add_argument("--fast", action="store_true",
                    help="reduced rep counts (CI smoke)")
    ap.add_argument("--smoke", action="store_true",
                    help="minimal scale — the fast '-m \"not slow\"' test "
                         "lane runs this; implies tiny sweeps")
    ap.add_argument("--reps", type=int, default=None,
                    help="override the Monte-Carlo rep count per point")
    ap.add_argument("--shard", action="store_true",
                    help="shard MC key batches over the local devices "
                         "(engine.Engine(shard=True))")
    ap.add_argument("--policies", default=None,
                    help="comma-separated registered policy names to sweep "
                         "(default: per-figure defaults; --smoke defaults "
                         "to every registered policy)")
    args = ap.parse_args(argv)

    from repro.core import policies as policy_registry

    from . import (efficiency, fig3, fig4, fig5, fig_churn, fig_decode,
                   fig_fleet, fig_transport, kernel_bench, overhead,
                   roofline_report)

    reps_explicit = args.reps is not None
    reps = args.reps if reps_explicit else (
        2 if args.smoke else (8 if args.fast else 40))
    shard = args.shard
    if args.policies is not None:
        swept = tuple(args.policies.split(","))
        for p in swept:
            policy_registry.get(p)  # fail loudly on typos, with known names
    else:
        # The smoke lane sweeps every registered policy through the churn
        # figure so a policy that breaks under jit/vmap fails the fast lane.
        swept = policy_registry.names() if args.smoke else None
    churn_policies = {} if swept is None else dict(policies=swept)
    fig_policies = {} if args.policies is None else dict(
        policies=tuple(p for p in swept))
    if args.smoke:
        sweep = (500,)
        churn_kw = dict(
            sweeps={name: ((axis[0], axis[-1]), mk, ax_name)
                    for name, (axis, mk, ax_name) in fig_churn.SWEEPS.items()},
            R=200, n_helpers=20,
        )
        decode_kw = dict(sweep=(0.0, 0.2), R=200, n_helpers=16,
                         offline_trials=2)
        fleet_kw = dict(task_sweep=(1, 4), R=120, n_helpers=10,
                        helpers_per_task=3, policies=("ccp", "naive"))
        transport_kw = dict(rtt_sweep=(0.0, 4.0), R=200, n_helpers=16)
    elif args.fast:
        sweep = (500, 1000)
        churn_kw = dict(
            sweeps={name: ((axis[0], axis[2]), mk, ax_name)
                    for name, (axis, mk, ax_name) in fig_churn.SWEEPS.items()},
        )
        decode_kw = dict(sweep=(0.0, 0.2), offline_trials=4)
        fleet_kw = dict(task_sweep=(1, 4, 8), R=200, n_helpers=12,
                        helpers_per_task=4)
        transport_kw = dict(rtt_sweep=(0.0, 1.0, 4.0), R=400, n_helpers=25)
    else:
        sweep = (1000, 2000, 4000, 8000)
        churn_kw = {}
        decode_kw = {}
        fleet_kw = {}
        transport_kw = {}
    small = args.fast or args.smoke
    # An explicit --reps is honored verbatim everywhere; the per-figure
    # scaling below only applies to the lane defaults.
    fig5_reps = reps if reps_explicit else max(reps // 2, 2 if small else 5)
    eff_reps = reps if reps_explicit else (min(reps, 4) if small else 20)
    jobs = {
        "fig3": lambda: fig3.run(reps=reps, r_sweep=sweep, shard=shard,
                                 **fig_policies),
        "fig4": lambda: fig4.run(reps=reps, r_sweep=sweep, shard=shard,
                                 **fig_policies),
        "fig5": lambda: fig5.run(reps=fig5_reps,
                                 r_sweep=(200, 400) if small
                                 else (200, 400, 800, 1600), shard=shard,
                                 **fig_policies),
        "fig_churn": lambda: fig_churn.run(reps=reps, shard=shard,
                                           **churn_policies, **churn_kw),
        "fig_decode": lambda: fig_decode.run(reps=reps, shard=shard,
                                             **decode_kw),
        "fig_fleet": lambda: fig_fleet.run(reps=reps, **fleet_kw),
        "fig_transport": lambda: fig_transport.run(reps=reps, shard=shard,
                                                   **fig_policies,
                                                   **transport_kw),
        "efficiency": lambda: efficiency.run(
            reps=eff_reps,
            R=400 if args.smoke else (2000 if args.fast else 8000),
            shard=shard),
        "overhead": overhead.run,
        "kernel": kernel_bench.run,
        "roofline": roofline_report.run,
    }
    only = set(args.only.split(",")) if args.only else set(jobs)
    failed = []
    print("name,us_per_call,derived")
    for name, job in jobs.items():
        if name not in only:
            continue
        t0 = time.time()
        try:
            job()
            print(f"# {name} done in {time.time() - t0:.1f}s", file=sys.stderr)
        except Exception:
            traceback.print_exc()
            failed.append(name)
    if failed:
        print(f"# FAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
