"""Coding-overhead characterization (paper §2: K as low as 5%, O(R) codec).

Two tables:
  1. decode failure probability vs (R, K, losses) — the fountain contract
     the framework's fault-tolerance envelope is built on;
  2. encode/decode wall time vs R — the O(R) complexity claim (per-block
     work is constant; we time the whole codec at fixed block size).
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import fountain

from .common import emit


def run() -> dict:
    fail_rows = []
    for R, K in ((64, 8), (64, 16), (256, 16), (256, 32), (1024, 64)):
        for n_lost in (1, 2, K // 2, K):
            p = fountain.decode_failure_prob(R, K, n_lost, trials=40, seed=0)
            fail_rows.append({"R": R, "K": K, "lost": n_lost, **p})

    time_rows = []
    for R in (64, 256, 1024):
        code = fountain.make_lt_code(R, max(R // 16, 4), seed=0)
        blocks = jax.random.normal(jax.random.PRNGKey(0), (R, 64))
        enc = jax.jit(lambda b: fountain.encode_ref(
            b, jax.numpy.asarray(code.idx), jax.numpy.asarray(code.mask)))
        enc(blocks).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(5):
            enc(blocks).block_until_ready()
        t_enc = (time.perf_counter() - t0) / 5
        # peeling decode with one systematic loss
        keep = np.setdiff1d(np.arange(code.n_coded), [R // 2])
        t0 = time.perf_counter()
        plan = fountain.peel_decode_plan(code, keep)
        t_plan = time.perf_counter() - t0
        time_rows.append({
            "R": R, "encode_us": t_enc * 1e6, "peel_plan_us": t_plan * 1e6,
            "peel_ok": plan is not None,
        })
    # O(R) check: 16x blocks should cost well under 16^2 x
    r0, r2 = time_rows[0], time_rows[-1]
    scaling = (r2["peel_plan_us"] / max(r0["peel_plan_us"], 1e-9)) / (1024 / 64)
    emit("overhead", {"failures": fail_rows, "timing": time_rows},
         derived=f"peel_scaling_vs_linear={scaling:.2f}")
    return {"failures": fail_rows, "timing": time_rows, "scaling": scaling}


if __name__ == "__main__":
    out = run()
    print(f"  peel scaling vs linear: x{out['scaling']:.2f}")
    for r in out["timing"]:
        print(f"  R={r['R']}: encode {r['encode_us']:.0f}us, "
              f"plan {r['peel_plan_us']:.0f}us, ok={r['peel_ok']}")
