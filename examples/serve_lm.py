"""Serving example: batched prefill/decode + CCP dispatch over heterogeneous
replicas.

Two engine replicas serve request batches; one replica is artificially
slowed (the paper's heterogeneous helper). The CCPDispatcher learns the
speed ratio from completion telemetry and shifts load — the serving-side
realization of Algorithm 1.

PYTHONPATH=src python examples/serve_lm.py
"""

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.runtime.serve_loop import CCPDispatcher, ServeEngine


def main():
    cfg = get_config("phi4-mini-3.8b", smoke=True)
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, max_len=64)

    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=(4, 16)).astype(np.int32)
               for _ in range(24)]

    # sanity: greedy generation is deterministic
    out1 = engine.generate(prompts[0], n_new=8)
    out2 = engine.generate(prompts[0], n_new=8)
    assert np.array_equal(out1, out2)
    print(f"generated {out1.shape[1]} tokens/request, batch {out1.shape[0]}")

    def fast(batch):
        return engine.generate(batch, n_new=4)

    def slow(batch):
        time.sleep(0.15)  # helper with less compute
        return engine.generate(batch, n_new=4)

    disp = CCPDispatcher([fast, slow])
    results, allocs = disp.run(prompts)
    assert all(r is not None for r in results)
    first, last = allocs[0], allocs[-1]
    print(f"first-round allocation {first.tolist()} -> last {last.tolist()}")
    print(f"fast-replica share grew from {first[0]/first.sum():.0%} to "
          f"{last[0]/last.sum():.0%} (CCP eq. 23 at the serving layer)")


if __name__ == "__main__":
    main()
