"""Quickstart: the paper's core loop in 60 lines.

1. Simulate CCP vs. the baselines on the paper's Scenario-1 setup.
2. Run a fountain-coded distributed matmul, kill a shard, recover y = Ax.

PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.ccp_paper import FIG3
from repro.core import baselines, coded_matmul, engine, simulator, theory

run_one = engine.Engine().run_one


def ccp_vs_baselines():
    print("== CCP vs baselines (paper Fig. 3a setup, R=2000, 5 reps) ==")
    cfg, R = FIG3[1], 2000
    Ts = {}
    for name, fn in (
        ("ccp", lambda k, c, r: run_one(k, c, "ccp", r)),
        ("best", lambda k, c, r: run_one(k, c, "best", r)),
        ("uncoded", lambda k, c, r: baselines.run_uncoded(k, c, r, "mean")),
        ("hcmm", baselines.run_hcmm),
    ):
        Ts[name] = np.mean([fn(jax.random.PRNGKey(i), cfg, R)["T"]
                            for i in range(5)])
    o = run_one(jax.random.PRNGKey(0), cfg, "ccp", R)
    t_opt = theory.t_opt_model1(R, cfg.K(R), o["a"], o["mu"])
    for k, v in Ts.items():
        print(f"  T_{k:8s} = {v:8.2f}s")
    print(f"  T_optimum  = {t_opt:8.2f}s   (eq. 27)")
    print(f"  CCP vs HCMM: {1 - Ts['ccp'] / Ts['hcmm']:+.1%}, "
          f"vs uncoded: {1 - Ts['ccp'] / Ts['uncoded']:+.1%}")
    print(f"  mean helper efficiency: {np.nanmean(o['efficiency']):.2%}\n")


def coded_offload():
    print("== Coded distributed matmul: lose a shard, still finish ==")
    plan = coded_matmul.plan_coded_matmul(rows=256, n_shards=4, overhead=0.5, bm=16)
    a = jax.random.normal(jax.random.PRNGKey(0), (256, 64))
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 32))
    out = coded_matmul.run(plan, a, x)
    for survivors in (np.arange(4), np.array([0, 2, 3])):
        y = coded_matmul.recover(plan, out, survivors)
        err = float(jnp.abs(y - a @ x).max())
        print(f"  survivors={survivors.tolist()}  max|err|={err:.2e}")
    print()


if __name__ == "__main__":
    ccp_vs_baselines()
    coded_offload()
