"""Elastic failover demo: train on 8 devices, hard-kill one, resume from the
async checkpoint on a smaller mesh, then re-admit the device and grow back.

PYTHONPATH=src python examples/elastic_failover.py
"""

import os

os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
)

import tempfile

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.data import SyntheticLM
from repro.models import build_model
from repro.optim import adamw
from repro.parallel import sharding as shd
from repro.runtime.elastic import ElasticConfig, ElasticTrainer


def main():
    cfg = get_config("phi4-mini-3.8b", smoke=True)
    model = build_model(cfg)
    opt_cfg = adamw.AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=300,
                                weight_decay=0.0)
    data = SyntheticLM(cfg.vocab, 32, 8, n_micro=1, seed=0)

    def build(mesh):
        rules = shd.make_rules(cfg, mesh)
        params, axes = model.init(jax.random.PRNGKey(0))
        p_sh = shd.param_shardings(mesh, axes, rules)
        params = jax.device_put(params, p_sh)
        opt = adamw.init(params)
        from repro.runtime.train_loop import make_train_step

        raw = jax.jit(make_train_step(model, opt_cfg, 1, pre_shaped=True))

        def step_fn(state, batch):
            p, o = state
            with mesh:
                p, o, m = raw(p, o, batch)
            return (p, o), m

        return (params, opt), step_fn, (p_sh, None)

    def batch_fn(step, mesh):
        return {k: jnp.asarray(v) for k, v in data.batch(step).items()}

    with tempfile.TemporaryDirectory() as d:
        tr = ElasticTrainer(ElasticConfig(ckpt_dir=d, ckpt_every=10), build)
        tr.rebuild(model_axis=2)
        print(f"mesh {tr.mesh.devices.shape}: training 25 steps")
        l1 = tr.run(25, batch_fn)
        print(f"  loss {l1[0]:.3f} -> {l1[-1]:.3f}")

        tr.fail_device(7, model_axis=2)
        print(f"device 7 FAILED -> mesh {tr.mesh.devices.shape}, "
              f"resumed at step {tr.step}")
        l2 = tr.run(25, batch_fn)
        print(f"  loss {l2[0]:.3f} -> {l2[-1]:.3f}")

        tr.recover_device(7, model_axis=2)
        print(f"device 7 re-admitted -> mesh {tr.mesh.devices.shape}, "
              f"step {tr.step}")
        l3 = tr.run(10, batch_fn)
        print(f"  loss {l3[0]:.3f} -> {l3[-1]:.3f}")
        assert l3[-1] < l1[0], "training must make net progress across failures"
        print("elastic failover complete")


if __name__ == "__main__":
    main()
