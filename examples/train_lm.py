"""End-to-end training driver: a small LM trained for a few hundred steps
with the full substrate (sharded params, microbatching, remat, AdamW,
deterministic data, async checkpointing, CCP step telemetry).

Default is CPU-sized; pass --preset 100m for the ~100M-parameter config
(same code path, sized for a real accelerator).

PYTHONPATH=src python examples/train_lm.py --steps 200 --devices 4 --mesh 4,1
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--devices", type=int, default=4)
    ap.add_argument("--mesh", default="4,1")
    ap.add_argument("--preset", choices=["tiny", "100m"], default="tiny")
    ap.add_argument("--coded-dp", action="store_true")
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm_ckpt")
    args = ap.parse_args()

    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={args.devices}"
    )
    sys.argv = [
        "train",
        "--arch", "mistral-nemo-12b",
        "--smoke",
        "--steps", str(args.steps),
        "--batch", "8" if args.preset == "tiny" else "64",
        "--seq", "64" if args.preset == "tiny" else "512",
        "--n-micro", "2",
        "--mesh", args.mesh,
        "--ckpt", args.ckpt,
        "--ckpt-every", "50",
    ] + (["--coded-dp"] if args.coded_dp else [])
    if args.preset == "100m":
        # ~100M params: widen the smoke config via overrides in launch.train
        # (kept as the same llama-family block, 12L x 768)
        os.environ["REPRO_TRAIN_OVERRIDES"] = (
            "n_layers=12,d_model=768,n_heads=12,n_kv_heads=4,d_ff=2048,vocab=32000"
        )
    from repro.launch.train import main as train_main

    loss = train_main()
    assert loss == loss, "NaN loss"
    print("example complete")


if __name__ == "__main__":
    main()
