"""The paper's Fig. 1 end-to-end on a device mesh: fountain-coded y = A x
offloaded across 8 'helper' shards (shard_map over the model axis), with a
straggler killed mid-task, plus the fused Pallas kernel path.

PYTHONPATH=src python examples/coded_offload.py
"""

import os

os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
)

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import coded_matmul
from repro.launch.mesh import make_host_mesh


def main():
    mesh = make_host_mesh(data=1, model=8)
    plan = coded_matmul.plan_coded_matmul(rows=1024, n_shards=8,
                                          overhead=0.5, bm=32,
                                          validate_losses=2)
    print(f"code: R={plan.code.R} source + K={plan.code.K} parity blocks, "
          f"{plan.blocks_per_shard} blocks/shard, "
          f"validated for any 2-shard loss")

    a = jax.random.normal(jax.random.PRNGKey(0), (1024, 256), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (256, 64), jnp.float32)

    # distributed compute: every device encodes + multiplies its own blocks
    out = coded_matmul.run(plan, a, x, mesh=mesh, axis="model")
    y_ref = a @ x

    for survivors in (np.arange(8), np.array([0, 1, 2, 4, 5, 6, 7]),
                      np.array([1, 2, 3, 4, 6, 7])):
        y = coded_matmul.recover(plan, out, survivors)
        err = float(jnp.abs(y - y_ref).max())
        lost = sorted(set(range(8)) - set(survivors.tolist()))
        print(f"  lost shards {lost or 'none'}: max|err| = {err:.2e}")

    # fused Pallas kernel path (interpret mode on CPU)
    out_k = coded_matmul.run(plan, a, x, use_pallas=True, interpret=True)
    err = float(jnp.abs(out_k - coded_matmul.run(plan, a, x)).max())
    print(f"  pallas fused-kernel path max|err| vs jnp: {err:.2e}")


if __name__ == "__main__":
    main()
